//! Quantization-scheme ablation bench (design-choice ablations DESIGN.md
//! calls out): weight-MSE and logit error across widths and granularities,
//! plus the Q7.9-network-wide vs per-layer int16 comparison the paper's
//! §6 setup implies.
//!
//! Run: `cargo bench --bench bench_quantizer`

use microai::graph::ir::LayerKind;
use microai::graph::{deploy_pipeline, resnet_v1_6_shapes, Graph};
use microai::nn::float_exec::{self, ActStats};
use microai::nn::int_exec;
use microai::quant::ptq::weight_mse;
use microai::quant::{quantize, QuantSpec};
use microai::util::prng::Pcg32;

fn setup(filters: usize) -> (Graph, Vec<Vec<f32>>, ActStats) {
    let mut g = resnet_v1_6_shapes("har", 1, &[128, 9], 6, filters);
    let mut rng = Pcg32::seeded(11);
    for n in g.nodes.iter_mut() {
        if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
            for v in w.data.iter_mut() {
                *v = rng.normal() * 0.3;
            }
            for v in b.data.iter_mut() {
                *v = 0.01;
            }
        }
    }
    let g = deploy_pipeline(&g);
    let inputs: Vec<Vec<f32>> = (0..16)
        .map(|_| (0..128 * 9).map(|_| rng.normal()).collect())
        .collect();
    let mut stats = ActStats::new(g.nodes.len());
    for x in &inputs {
        float_exec::run(&g, x, Some(&mut stats));
    }
    (g, inputs, stats)
}

fn logit_rmse(g: &Graph, qg: &microai::quant::QuantizedGraph, inputs: &[Vec<f32>]) -> f64 {
    let mut se = 0.0f64;
    let mut n = 0usize;
    for x in inputs {
        let fl = float_exec::run(g, x, None);
        for (u, v) in fl.iter().zip(int_exec::run(qg, x)) {
            se += ((u - v) as f64).powi(2);
            n += 1;
        }
    }
    (se / n as f64).sqrt()
}

fn main() {
    println!("==== quantization-scheme ablation (UCI-HAR ResNet, f=32) ====");
    let (g, inputs, stats) = setup(32);
    println!(
        "{:<28} {:>14} {:>14} {:>12}",
        "scheme", "weight MSE", "logit RMSE", "weights(B)"
    );
    let schemes = [
        QuantSpec::int8_per_layer(),
        QuantSpec::int8_per_filter(),
        QuantSpec::int9_per_layer(),
        QuantSpec::int16_per_layer(),
        QuantSpec::int16_q7_9(),
    ];
    let mut results = Vec::new();
    for spec in schemes {
        let qg = quantize(&g, &stats, spec);
        let mse = weight_mse(&g, &qg);
        let rmse = logit_rmse(&g, &qg, &inputs);
        println!(
            "{:<28} {:>14.3e} {:>14.5} {:>12}",
            spec.label(),
            mse,
            rmse,
            qg.weight_bytes()
        );
        results.push((spec.label(), mse, rmse));
    }

    // Ablation claims (paper §4.1.3, §7, §6):
    let get = |label: &str| results.iter().find(|r| r.0 == label).unwrap().clone();
    let (_, mse_l8, rmse_l8) = get("int8-per-layer");
    let (_, mse_f8, _) = get("int8-per-filter");
    let (_, _, rmse_9) = get("int9-per-layer");
    let (_, _, rmse_16) = get("int16-per-layer");
    let (_, _, rmse_q79) = get("int16-Q7.9");
    assert!(mse_f8 <= mse_l8, "per-filter must not increase weight MSE");
    assert!(rmse_9 < rmse_l8, "one extra bit must reduce logit error");
    assert!(rmse_16 < rmse_9);
    // Per-layer int16 beats the fixed network-wide Q7.9 (finer formats).
    assert!(rmse_16 <= rmse_q79 * 1.001, "{rmse_16} vs {rmse_q79}");
    println!("\nablation orderings: OK");
    println!("(per-filter ≤ per-layer MSE; int9 < int8; int16 < int9; per-layer ≤ Q7.9)");
}
