//! Serving scheduler benchmark: the sharded, batch-aware cascade
//! scheduler vs the single-channel worker pool it replaced (one shared
//! `Mutex<Receiver>`, one request per dispatch).
//!
//! The interesting column is host-side throughput (requests/s of the
//! scheduler itself): sharding removes the lock convoy on the shared
//! receiver and micro-batching amortizes dispatch + arena setup, so the
//! sharded scheduler should win from ~4 workers up.
//!
//! Run: `cargo bench --bench bench_serving`
//! CI smoke (1 timed iteration per arm): `cargo bench --bench bench_serving -- --smoke`

use std::sync::Arc;
use std::time::Duration;

use microai::coordinator::serving::{
    run_cascade_sessions, run_cascade_single_channel, CascadeConfig, Request,
};
use microai::graph::ir::LayerKind;
use microai::graph::{deploy_pipeline, resnet_v1_6_shapes};
use microai::mcu::board::SPARKFUN_EDGE;
use microai::nn::float_exec::{self, ActStats};
use microai::nn::SessionBuilder;
use microai::quant::{quantize, QuantSpec, QuantizedGraph};
use microai::util::bench::{black_box, print_header, Bencher};
use microai::util::prng::Pcg32;

fn tiny_qgraph(filters: usize, seed: u64) -> Arc<QuantizedGraph> {
    let mut g = resnet_v1_6_shapes("t", 1, &[32, 3], 4, filters);
    let mut rng = Pcg32::seeded(seed);
    for n in g.nodes.iter_mut() {
        if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
            for v in w.data.iter_mut() {
                *v = rng.normal() * 0.4;
            }
            for v in b.data.iter_mut() {
                *v = 0.01;
            }
        }
    }
    let g = deploy_pipeline(&g);
    let mut stats = ActStats::new(g.nodes.len());
    let mut rng = Pcg32::seeded(seed + 9);
    for _ in 0..6 {
        let x: Vec<f32> = (0..96).map(|_| rng.normal()).collect();
        float_exec::run(&g, &x, Some(&mut stats));
    }
    Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()))
}

fn requests(n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|id| Request { id: id as u64, input: (0..96).map(|_| rng.normal()).collect() })
        .collect()
}

/// Quantized transformer for the second-model-family cascade (ISSUE 6):
/// same seq/vocab for both tiers so a little 1-block model can escalate
/// to a big 2-block one.
fn tiny_tx_qgraph(blocks: usize, seed: u64) -> Arc<QuantizedGraph> {
    const VOCAB: u32 = 16;
    let mut g = microai::graph::build::transformer("tx", 12, VOCAB as usize, 16, 2, blocks, 2, 4);
    let mut rng = Pcg32::seeded(seed);
    for n in g.nodes.iter_mut() {
        match &mut n.kind {
            LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } => {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.3;
                }
                for v in b.data.iter_mut() {
                    *v = rng.normal() * 0.05;
                }
            }
            LayerKind::Embedding { w } => {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.5;
                }
            }
            LayerKind::LayerNorm { gamma, beta, .. } => {
                for v in gamma.iter_mut() {
                    *v = 1.0 + rng.normal() * 0.2;
                }
                for v in beta.iter_mut() {
                    *v = rng.normal() * 0.1;
                }
            }
            LayerKind::SelfAttention { w, .. } => {
                for t in [&mut w.wq, &mut w.wk, &mut w.wv, &mut w.wo] {
                    for v in t.data.iter_mut() {
                        *v = rng.normal() * 0.3;
                    }
                }
                for t in [&mut w.bq, &mut w.bk, &mut w.bv, &mut w.bo] {
                    for v in t.data.iter_mut() {
                        *v = rng.normal() * 0.05;
                    }
                }
            }
            _ => {}
        }
    }
    let g = deploy_pipeline(&g);
    let mut stats = ActStats::new(g.nodes.len());
    let mut rng = Pcg32::seeded(seed + 9);
    for _ in 0..6 {
        let x: Vec<f32> = (0..12).map(|_| rng.below(VOCAB) as f32).collect();
        float_exec::run(&g, &x, Some(&mut stats));
    }
    Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()))
}

fn token_requests(n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|id| Request {
            id: id as u64,
            input: (0..12).map(|_| rng.below(16) as f32).collect(),
        })
        .collect()
}

fn main() {
    let mut smoke = std::env::var("MICROAI_BENCH_SMOKE").is_ok();
    let mut out_path = String::from("BENCH_serving.json");
    let mut argv = std::env::args().skip(1);
    while let Some(a) = argv.next() {
        match a.as_str() {
            "--smoke" => smoke = true,
            "--out" => out_path = argv.next().expect("--out needs a path"),
            "--bench" => {} // appended by `cargo bench`
            other => eprintln!("bench_serving: ignoring unknown arg {other}"),
        }
    }
    let mut json_rows: Vec<microai::util::json::Json> = Vec::new();
    // --smoke: exactly one timed iteration per arm (CI exercises the
    // whole path without paying for statistics).
    let b = if smoke {
        Bencher { warmup: Duration::ZERO, measure: Duration::ZERO, max_iters: 1 }
    } else {
        Bencher::default()
    };
    let n_requests = if smoke { 96 } else { 1024 };

    let little = tiny_qgraph(8, 1);
    let big = tiny_qgraph(16, 2);
    let little_t = SessionBuilder::fixed_qmn(little).board(&SPARKFUN_EDGE).build();
    let big_t = SessionBuilder::fixed_qmn(big).board(&SPARKFUN_EDGE).build();
    let reqs = requests(n_requests, 3);

    // Pinned Poisson-clock seed: every cfg below names it explicitly so
    // the --smoke output (and its JSON artifact) is reproducible
    // run-to-run instead of silently riding whatever the default is.
    const BENCH_SEED: u64 = 0x5EED;

    print_header(&format!(
        "cascade scheduler throughput ({n_requests} requests, threshold 0.8)"
    ));
    for workers in [1usize, 2, 4, 8] {
        let cfg = CascadeConfig {
            threshold: 0.8,
            workers,
            seed: BENCH_SEED,
            ..CascadeConfig::default()
        };
        let r = b.run_throughput(
            &format!("sharded+batched   w={workers}"),
            n_requests as f64,
            "req/s",
            || {
                let s = run_cascade_sessions(&little_t, &big_t, &cfg, reqs.clone(), None);
                black_box(s.responses.len());
            },
        );
        println!("{}", r.report());
        let sharded_ns = r.median_ns;

        let r = b.run_throughput(
            &format!("single-channel    w={workers}"),
            n_requests as f64,
            "req/s",
            || {
                let out = run_cascade_single_channel(&little_t, &big_t, 0.8, workers, reqs.clone());
                black_box(out.len());
            },
        );
        println!("{}", r.report());
        println!(
            "  -> sharded/single speedup at w={workers}: {:.2}x",
            r.median_ns / sharded_ns.max(1.0)
        );
        json_rows.push(microai::util::json::Json::obj(vec![
            ("workers", microai::util::json::Json::num(workers as f64)),
            // Worker micro-batch size: the sharded arm serves each batch
            // through ONE batch-folded Session::infer call (PR-8); the
            // single-channel baseline is always batch 1.
            ("batch", microai::util::json::Json::num(cfg.max_batch as f64)),
            ("sharded_ns", microai::util::json::Json::num(sharded_ns)),
            ("single_channel_ns", microai::util::json::Json::num(r.median_ns)),
            (
                "sharded_speedup",
                microai::util::json::Json::num(r.median_ns / sharded_ns.max(1.0)),
            ),
        ]));
    }

    // ISSUE 6: the transformer family through the same cascade — a
    // 1-block little model escalating to a 2-block big one on token-id
    // requests. Runs in --smoke so CI exercises the fused attention /
    // layernorm / softmax session path end to end.
    print_header(&format!("transformer cascade ({n_requests} token requests, threshold 0.8)"));
    let tx_little = SessionBuilder::fixed_qmn(tiny_tx_qgraph(1, 21)).board(&SPARKFUN_EDGE).build();
    let tx_big = SessionBuilder::fixed_qmn(tiny_tx_qgraph(2, 22)).board(&SPARKFUN_EDGE).build();
    let tx_reqs = token_requests(n_requests, 23);
    let mut tx_rows: Vec<microai::util::json::Json> = Vec::new();
    for workers in [1usize, 4] {
        let cfg = CascadeConfig {
            threshold: 0.8,
            workers,
            seed: BENCH_SEED,
            ..CascadeConfig::default()
        };
        let r = b.run_throughput(
            &format!("transformer cascade w={workers}"),
            n_requests as f64,
            "req/s",
            || {
                let s = run_cascade_sessions(&tx_little, &tx_big, &cfg, tx_reqs.clone(), None);
                black_box(s.responses.len());
            },
        );
        println!("{}", r.report());
        tx_rows.push(microai::util::json::Json::obj(vec![
            ("workers", microai::util::json::Json::num(workers as f64)),
            ("batch", microai::util::json::Json::num(cfg.max_batch as f64)),
            ("sharded_ns", microai::util::json::Json::num(r.median_ns)),
        ]));
    }

    // Queueing-model flavor: one saturated run, reported not timed. In
    // smoke mode it runs on ONE worker: with a single worker the
    // host-time request→worker assignment is trivial, so the pinned
    // arrival seed makes the queue statistics (and the JSON artifact)
    // bit-reproducible run-to-run; full mode keeps the 4-worker flavor,
    // whose queue stats are conditioned on that run's assignment.
    let sat_workers = if smoke { 1 } else { 4 };
    let cfg = CascadeConfig {
        threshold: 0.8,
        workers: sat_workers,
        arrival_rate_hz: 1e5,
        seed: BENCH_SEED,
        ..CascadeConfig::default()
    };
    let s = run_cascade_sessions(&little_t, &big_t, &cfg, reqs.clone(), None);
    let lat = s.latency.expect("board-priced sessions");
    let dev = s.device_latency.expect("board-priced sessions");
    println!(
        "\nsaturated arrivals (100k req/s, {sat_workers} workers): total p50 {:.1} ms = \
         queue p50 {:.1} ms + device p50 {:.1} ms; queue depth p99 {:.0}; utilization {}",
        lat.p50,
        s.queue_latency.p50,
        dev.p50,
        s.queue_depth.p99,
        s.worker_utilization
            .iter()
            .map(|u| format!("{:.0}%", u * 100.0))
            .collect::<Vec<_>>()
            .join(" "),
    );

    // Machine-readable trajectory (uploaded as a CI artifact).
    use microai::util::json::Json;
    let doc = Json::obj(vec![
        ("version", Json::num(1.0)),
        ("bench", Json::str("serving")),
        ("mode", Json::str(if smoke { "smoke" } else { "full" })),
        ("n_requests", Json::num(n_requests as f64)),
        ("scheduler_race", Json::Arr(json_rows)),
        ("transformer_cascade", Json::Arr(tx_rows)),
        (
            "saturated",
            Json::obj(vec![
                ("workers", Json::num(sat_workers as f64)),
                ("seed", Json::num(BENCH_SEED as f64)),
                ("total_p50_ms", Json::num(lat.p50)),
                ("queue_p50_ms", Json::num(s.queue_latency.p50)),
                ("device_p50_ms", Json::num(dev.p50)),
                ("queue_depth_p99", Json::num(s.queue_depth.p99)),
            ]),
        ),
    ]);
    let mut text = doc.to_string();
    text.push('\n');
    std::fs::write(&out_path, text).expect("write bench json");
    println!("wrote {out_path}");
}
