//! Table A1/A2: float32 inference time per input on MCU vs CPU vs GPU.
//!
//!   MCU — the calibrated STM32Cube.AI Nucleo model (Table A2's MCU row is
//!         the Cube.AI float32 series).
//!   CPU — REAL measurement: the `fwd` HLO artifact executed batched via
//!         PJRT on this host (batch = eval_batch, amortized per input, as
//!         the paper amortizes batch-512 runs).
//!   GPU — throughput model from the paper's Quadro P2000M column
//!         (no GPU in this environment; DESIGN.md §3).
//!
//! Run: `make artifacts && cargo bench --bench bench_host_a2`

use microai::coordinator::trainer::Trainer;
use microai::mcu::cost::{har_graph, validate_latency};
use microai::mcu::paper_data::{self, DType, FILTERS};
use microai::runtime::exec::{lit_f32, to_f32};
use microai::runtime::Runtime;
use microai::util::prng::Pcg32;

fn main() -> anyhow::Result<()> {
    println!("==== Table A2: float32 inference time per input (ms) ====");
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP CPU rows (run `make artifacts`): {e}");
            return Ok(());
        }
    };

    // MCU model (Cube.AI float on Nucleo).
    let mcu_series = paper_data::find(
        &paper_data::TABLE_A4_MS, "STM32Cube.AI", "NucleoL452REP", DType::F32).unwrap();
    let mcu = validate_latency(mcu_series);

    // Host CPU: available artifact filter counts.
    let mut cpu_rows: Vec<(usize, f64)> = Vec::new();
    let tags: Vec<String> = rt
        .manifest
        .models
        .values()
        .filter(|m| m.dataset == "har")
        .map(|m| m.tag.clone())
        .collect();
    for tag in &tags {
        let spec = rt.spec(tag)?.clone();
        let mut trainer = Trainer::new(&rt, 1);
        let state = trainer.init(tag)?;
        let exe = rt.compile_model(tag, "fwd")?;
        let b = spec.eval_batch;
        let ex_len = spec.example_len();
        let mut rng = Pcg32::seeded(5);
        let xs: Vec<f32> = (0..b * ex_len).map(|_| rng.normal()).collect();
        let mut shape = vec![b];
        shape.extend_from_slice(&spec.input_shape);
        let mut inputs: Vec<xla::Literal> = state.params.to_vec();
        inputs.push(lit_f32(&xs, &shape)?);
        // Warmup + timed runs.
        for _ in 0..3 {
            let _ = exe.run(&inputs)?;
        }
        let mut samples = Vec::new();
        for _ in 0..10 {
            let t0 = std::time::Instant::now();
            let out = exe.run(&inputs)?;
            let _ = to_f32(&out[0])?;
            samples.push(t0.elapsed().as_secs_f64() * 1e3 / b as f64);
        }
        cpu_rows.push((spec.filters, microai::util::stats::median(&samples)));
    }
    cpu_rows.sort_by(|a, b| a.0.cmp(&b.0));

    println!(
        "\n{:<22} {}",
        "Platform",
        FILTERS.iter().map(|f| format!("{f:>9}")).collect::<String>()
    );
    print!("{:<22}", "MCU (model)");
    for v in &mcu.predicted {
        print!("{v:>9.1}");
    }
    println!();
    print!("{:<22}", "MCU (paper)");
    for v in paper_data::TABLE_A2_MCU_MS {
        print!("{v:>9.1}");
    }
    println!();
    print!("{:<22}", "CPU host (measured)");
    for f in FILTERS {
        match cpu_rows.iter().find(|(ff, _)| *ff == f) {
            Some((_, ms)) => print!("{ms:>9.4}"),
            None => print!("{:>9}", "-"),
        }
    }
    println!("   (artifact filters: {:?})", cpu_rows.iter().map(|r| r.0).collect::<Vec<_>>());
    print!("{:<22}", "CPU (paper i7-8850H)");
    for v in paper_data::TABLE_A2_CPU_MS {
        print!("{v:>9.4}");
    }
    println!();
    print!("{:<22}", "GPU (paper P2000M)");
    for v in paper_data::TABLE_A2_GPU_MS {
        print!("{v:>9.4}");
    }
    println!("   (GPU column: paper values; no GPU in this testbed)");

    // The A2 headline: the MCU runs 3-5 orders of magnitude slower than
    // CPU/GPU — verify our measured host CPU reproduces that gap.
    if let Some((f, cpu_ms)) = cpu_rows.last() {
        let g = har_graph(*f);
        let mcu_ms = {
            let board = microai::mcu::board::Board::by_name("NucleoL452REP").unwrap();
            let model = microai::mcu::cost::LatencyModel::calibrate(mcu_series, board);
            model.latency_s(&g, board) * 1e3
        };
        let ratio = mcu_ms / cpu_ms;
        println!("\nMCU/CPU slowdown at f={f}: {ratio:.0}x (paper: ~{:.0}x at f=80)",
            paper_data::TABLE_A2_MCU_MS[6] / paper_data::TABLE_A2_CPU_MS[6]);
        assert!(ratio > 100.0, "MCU must be orders of magnitude slower");
    }
    Ok(())
}
