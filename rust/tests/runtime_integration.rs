//! Integration tests over the PJRT runtime + coordinator: these exercise
//! the REAL artifacts produced by `make artifacts` (skipped when absent).
//!
//! The key cross-layer contract tested here: the Rust float executor
//! (`nn::float_exec`) reproduces the JAX `fwd` artifact's logits on the
//! same weights, so PTQ calibration and integer inference in Rust operate
//! on the exact network that was trained through the HLO path.

use microai::coordinator::deployer;
use microai::coordinator::trainer::{LrSchedule, Trainer};
use microai::datasets;
use microai::runtime::exec::{lit_f32, to_f32};
use microai::runtime::Runtime;

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::open_default() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn kernel_artifact_matches_rust_fixed_point_semantics() {
    let Some(rt) = runtime_or_skip() else { return };
    // kernel_fixed_matmul.hlo.txt: (32,24)x(24,16) int8 fixed matmul with
    // bias, shift via multiplier, ReLU — the L1 Pallas kernel. Compare
    // against the Rust scalar reference from fixedpoint::ops.
    let exe = rt.compile("kernel_fixed_matmul.hlo.txt").expect("compile kernel");
    let (m, k, n) = (32usize, 24usize, 16usize);
    let mut rng = microai::util::prng::Pcg32::seeded(7);
    let xq: Vec<f32> = (0..m * k).map(|_| (rng.below(255) as i32 - 128) as f32).collect();
    let wq: Vec<f32> = (0..k * n).map(|_| (rng.below(255) as i32 - 128) as f32).collect();
    let bq: Vec<f32> = (0..n).map(|_| (rng.below(4096) as i32 - 2048) as f32).collect();
    let shift = 5i32;
    let mult = (2.0f32).powi(-shift);
    let out = exe
        .run(&[
            lit_f32(&xq, &[m, k]).unwrap(),
            lit_f32(&wq, &[k, n]).unwrap(),
            lit_f32(&bq, &[n]).unwrap(),
            xla::Literal::scalar(mult),
        ])
        .expect("run kernel");
    let got = to_f32(&out[0]).unwrap();
    assert_eq!(got.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc: i64 = bq[j] as i64;
            for t in 0..k {
                acc += (xq[i * k + t] as i64) * (wq[t * n + j] as i64);
            }
            let v = microai::fixedpoint::ops::sat_mul_shift(acc, shift, 8).max(0);
            assert_eq!(
                got[i * n + j], v as f32,
                "mismatch at ({i},{j}): kernel {} vs rust {v}",
                got[i * n + j]
            );
        }
    }
}

#[test]
fn rust_float_engine_matches_fwd_artifact() {
    let Some(rt) = runtime_or_skip() else { return };
    let tag = "har_f8";
    let spec = rt.spec(tag).expect("spec").clone();
    let mut trainer = Trainer::new(&rt, 3);
    let state = trainer.init(tag).expect("init");
    let params = trainer.params_to_host(&state).expect("params");
    // Float graph WITHOUT fusion first, then deployed (fused) — both must
    // match the artifact.
    let graph = microai::graph::resnet_v1_6(
        tag, spec.dims, &spec.input_shape, spec.classes, params.clone());
    let deployed = microai::graph::deploy_pipeline(&graph);

    // One eval batch through the fwd artifact.
    let exe = rt.compile_model(tag, "fwd").expect("fwd");
    let b = spec.eval_batch;
    let ex_len = spec.example_len();
    let mut rng = microai::util::prng::Pcg32::seeded(11);
    let xs: Vec<f32> = (0..b * ex_len).map(|_| rng.normal()).collect();
    let mut shape = vec![b];
    shape.extend_from_slice(&spec.input_shape);
    let mut inputs: Vec<xla::Literal> = state.params.to_vec();
    inputs.push(lit_f32(&xs, &shape).unwrap());
    let logits = to_f32(&exe.run(&inputs).expect("fwd run")[0]).unwrap();

    for ex in 0..4 {
        let x = &xs[ex * ex_len..(ex + 1) * ex_len];
        let want = &logits[ex * spec.classes..(ex + 1) * spec.classes];
        for g in [&graph, &deployed] {
            let got = microai::nn::float_exec::run(g, x, None);
            for (u, v) in got.iter().zip(want) {
                assert!(
                    (u - v).abs() < 1e-3,
                    "engine {} vs artifact {} (example {ex})",
                    u, v
                );
            }
        }
    }
}

#[test]
fn training_reduces_loss_on_synthetic_har() {
    let Some(rt) = runtime_or_skip() else { return };
    let tag = "har_f8";
    let data = datasets::load("har", 5).unwrap();
    let mut trainer = Trainer::new(&rt, 5);
    let mut state = trainer.init(tag).expect("init");
    let sched = LrSchedule { initial: 0.05, factor: 0.13, milestones: vec![40], warmup: 10 };
    trainer.train(&mut state, &data, "train", 50, &sched, 0).expect("train");
    let first: f32 = state.losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = state.losses[state.losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(
        last < first * 0.8,
        "loss did not drop: first {first} last {last}"
    );
}

#[test]
fn qat_training_step_runs_from_rust() {
    let Some(rt) = runtime_or_skip() else { return };
    let tag = "har_f8";
    let data = datasets::load("har", 6).unwrap();
    let mut trainer = Trainer::new(&rt, 6);
    let mut state = trainer.init(tag).expect("init");
    let sched = LrSchedule { initial: 0.01, factor: 0.1, milestones: vec![], warmup: 10 };
    trainer.train(&mut state, &data, "qat8_train", 3, &sched, 0).expect("qat");
    assert_eq!(state.losses.len(), 3);
    assert!(state.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn end_to_end_ptq_pipeline_accuracy_above_chance() {
    let Some(rt) = runtime_or_skip() else { return };
    let tag = "har_f8";
    let spec = rt.spec(tag).unwrap().clone();
    let data = datasets::load("har", 7).unwrap();
    let mut trainer = Trainer::new(&rt, 7);
    let mut state = trainer.init(tag).unwrap();
    let sched = LrSchedule { initial: 0.05, factor: 0.13, milestones: vec![60, 90], warmup: 10 };
    trainer.train(&mut state, &data, "train", 100, &sched, 0).unwrap();

    let params = trainer.params_to_host(&state).unwrap();
    let graph = deployer::build_deployed_graph(&spec, params);
    let float_acc = deployer::float_accuracy(&graph, &data);
    let (_q16, acc16) = deployer::ptq_accuracy(
        &graph, &data, microai::quant::QuantSpec::int16_per_layer(), 64);
    assert!(float_acc > 0.4, "float acc {float_acc} (chance = 0.167)");
    // The paper's central claim: int16 PTQ tracks float accuracy.
    assert!(
        (float_acc - acc16).abs() < 0.05,
        "int16 {acc16} vs float {float_acc}"
    );
}
