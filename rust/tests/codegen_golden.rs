//! Golden test for the C code generator: compile the emitted library with
//! the host C compiler and check bit-exactness against the Rust integer
//! engine on random inputs. Skipped when no `cc` is available.

use std::process::Command;

use microai::graph::ir::LayerKind;
use microai::graph::{deploy_pipeline, resnet_v1_6_shapes};
use microai::nn::float_exec::ActStats;
use microai::quant::{quantize, QuantSpec, QuantizedGraph};
use microai::util::prng::Pcg32;

fn find_cc() -> Option<String> {
    for cc in ["cc", "gcc", "clang"] {
        if Command::new(cc).arg("--version").output().map(|o| o.status.success()).unwrap_or(false)
        {
            return Some(cc.to_string());
        }
    }
    None
}

fn quantized_resnet(seed: u64, width: u32) -> QuantizedGraph {
    let mut g = resnet_v1_6_shapes("t", 1, &[32, 3], 4, 8);
    let mut rng = Pcg32::seeded(seed);
    for n in g.nodes.iter_mut() {
        if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
            for v in w.data.iter_mut() {
                *v = rng.normal() * 0.4;
            }
            for v in b.data.iter_mut() {
                *v = rng.normal() * 0.05;
            }
        }
    }
    let g = deploy_pipeline(&g);
    let mut stats = ActStats::new(g.nodes.len());
    for _ in 0..6 {
        let x: Vec<f32> = (0..96).map(|_| rng.normal()).collect();
        microai::nn::float_exec::run(&g, &x, Some(&mut stats));
    }
    let spec = if width == 8 {
        QuantSpec::int8_per_layer()
    } else {
        QuantSpec::int16_per_layer()
    };
    quantize(&g, &stats, spec)
}

fn run_golden(width: u32, seed: u64) {
    run_golden_graph(quantized_resnet(seed, width), &format!("{width}_{seed}"));
}

fn run_golden_graph(qg: QuantizedGraph, tag: &str) {
    run_golden_inputs(qg, tag, |rng, len| (0..len).map(|_| rng.normal()).collect())
}

fn run_golden_inputs(
    qg: QuantizedGraph,
    tag: &str,
    mut sample: impl FnMut(&mut Pcg32, usize) -> Vec<f32>,
) {
    let Some(cc) = find_cc() else {
        eprintln!("SKIP: no host C compiler");
        return;
    };
    let width = qg.width;
    let lib = microai::codegen::generate(&qg);
    let dir = std::env::temp_dir().join(format!("microai_golden_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    microai::codegen::write_to(&lib, &dir).unwrap();

    // Test harness main.c: reads payload input values on stdin, prints the
    // output payloads.
    let main_c = r#"
#include <stdio.h>
#include "model.h"
int main(void) {
    static number_t input[MODEL_INPUT_SAMPLES][MODEL_INPUT_CHANNELS];
    static number_t output[MODEL_OUTPUT_UNITS];
    for (int s = 0; s < MODEL_INPUT_SAMPLES; s++)
        for (int c = 0; c < MODEL_INPUT_CHANNELS; c++) {
            long v; if (scanf("%ld", &v) != 1) return 1;
            input[s][c] = (number_t)v;
        }
    cnn(input, output);
    for (int i = 0; i < MODEL_OUTPUT_UNITS; i++) printf("%d\n", (int)output[i]);
    return 0;
}
"#;
    std::fs::write(dir.join("main.c"), main_c).unwrap();
    let bin = dir.join("golden");
    let out = Command::new(&cc)
        .args(["-O2", "-o"])
        .arg(&bin)
        .arg(dir.join("main.c"))
        .arg(dir.join("model.c"))
        .arg("-I")
        .arg(&dir)
        .output()
        .expect("cc run");
    assert!(
        out.status.success(),
        "cc failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );

    // Random float inputs -> quantize at INPUT_SCALE_FACTOR -> feed C.
    let mut rng = Pcg32::seeded(77);
    let ex_len: usize = qg.graph.input_shape.iter().product();
    let in_fmt = microai::fixedpoint::QFormat::new(width, qg.act_n[0]);
    for _ in 0..5 {
        let xf: Vec<f32> = sample(&mut rng, ex_len);
        let payload: Vec<i32> = xf.iter().map(|&v| in_fmt.quantize(v)).collect();
        let stdin_text: String =
            payload.iter().map(|p| p.to_string()).collect::<Vec<_>>().join("\n");
        let out = {
            use std::io::Write;
            let mut child = Command::new(&bin)
                .stdin(std::process::Stdio::piped())
                .stdout(std::process::Stdio::piped())
                .spawn()
                .unwrap();
            child.stdin.as_mut().unwrap().write_all(stdin_text.as_bytes()).unwrap();
            let out = child.wait_with_output().unwrap();
            assert!(out.status.success());
            String::from_utf8(out.stdout).unwrap()
        };
        let c_payloads: Vec<i32> =
            out.lines().map(|l| l.trim().parse().unwrap()).collect();

        // Rust engine on the same float input; compare output payloads.
        let rust_logits = microai::nn::int_exec::run(&qg, &xf);
        let out_fmt = microai::fixedpoint::QFormat::new(width, qg.act_n[qg.graph.output_id()]);
        let rust_payloads: Vec<i32> =
            rust_logits.iter().map(|&v| out_fmt.quantize(v)).collect();
        assert_eq!(
            c_payloads, rust_payloads,
            "C and Rust integer engines disagree (width {width})"
        );
    }
}

/// Randomized 2-block transformer plus a token-id input sampler. The
/// deployment pipeline keeps the output softmax (`strip_softmax = false`
/// in the builder), so the emitted C ends in the fixed-point softmax.
fn quantized_transformer(seed: u64, width: u32) -> (QuantizedGraph, u32, usize) {
    const VOCAB: u32 = 24;
    let mut g = microai::graph::build::transformer("ctx", 12, VOCAB as usize, 16, 2, 2, 2, 4);
    let mut rng = Pcg32::seeded(seed);
    for n in g.nodes.iter_mut() {
        match &mut n.kind {
            LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } => {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.3;
                }
                for v in b.data.iter_mut() {
                    *v = rng.normal() * 0.05;
                }
            }
            LayerKind::Embedding { w } => {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.5;
                }
            }
            LayerKind::LayerNorm { gamma, beta, .. } => {
                for v in gamma.iter_mut() {
                    *v = 1.0 + rng.normal() * 0.2;
                }
                for v in beta.iter_mut() {
                    *v = rng.normal() * 0.1;
                }
            }
            LayerKind::SelfAttention { w, .. } => {
                for t in [&mut w.wq, &mut w.wk, &mut w.wv, &mut w.wo] {
                    for v in t.data.iter_mut() {
                        *v = rng.normal() * 0.3;
                    }
                }
                for t in [&mut w.bq, &mut w.bk, &mut w.bv, &mut w.bo] {
                    for v in t.data.iter_mut() {
                        *v = rng.normal() * 0.05;
                    }
                }
            }
            _ => {}
        }
    }
    let g = deploy_pipeline(&g);
    let ex_len: usize = g.input_shape.iter().product();
    let mut stats = ActStats::new(g.nodes.len());
    for _ in 0..6 {
        let x: Vec<f32> = (0..ex_len).map(|_| rng.below(VOCAB) as f32).collect();
        microai::nn::float_exec::run(&g, &x, Some(&mut stats));
    }
    let spec = if width == 8 {
        QuantSpec::int8_per_layer()
    } else {
        QuantSpec::int16_per_layer()
    };
    (quantize(&g, &stats, spec), VOCAB, ex_len)
}

fn run_golden_transformer(width: u32, seed: u64) {
    let (qg, vocab, _) = quantized_transformer(seed, width);
    // Token ids quantize exactly (the embedding input is pinned to n = 0),
    // so the C binary and the Rust engine see identical payloads.
    run_golden_inputs(qg, &format!("tx_{width}_{seed}"), |rng, len| {
        (0..len).map(|_| rng.below(vocab) as f32).collect()
    });
}

#[test]
fn c_transformer_int8_bit_exact_with_rust_engine() {
    run_golden_transformer(8, 3);
}

#[test]
fn c_transformer_int16_bit_exact_with_rust_engine() {
    run_golden_transformer(16, 4);
}

#[test]
fn c_int8_bit_exact_with_rust_engine() {
    run_golden(8, 1);
}

#[test]
fn c_int16_bit_exact_with_rust_engine() {
    run_golden(16, 2);
}

#[test]
fn c_odd_pool_remainder_bit_exact_with_rust_engine() {
    // SMNIST-style odd spatial dim (39): the generated pooling remainder
    // windows must match nn::int_ops bit-for-bit (and the GEMM-lowered
    // conv/dense path feeding them).
    let mut g = microai::graph::build::cnn("odd", 1, &[39, 4], 3, &[8], 3, 16);
    let mut rng = Pcg32::seeded(9);
    for n in g.nodes.iter_mut() {
        if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
            for v in w.data.iter_mut() {
                *v = rng.normal() * 0.4;
            }
            for v in b.data.iter_mut() {
                *v = rng.normal() * 0.05;
            }
        }
    }
    let g = deploy_pipeline(&g);
    let mut stats = ActStats::new(g.nodes.len());
    for _ in 0..6 {
        let x: Vec<f32> = (0..39 * 4).map(|_| rng.normal()).collect();
        microai::nn::float_exec::run(&g, &x, Some(&mut stats));
    }
    run_golden_graph(quantize(&g, &stats, QuantSpec::int8_per_layer()), "oddpool_8");
}
