//! Cross-engine parity and quantization-claim tests that do not need the
//! PJRT artifacts: float engine vs integer engines on randomized networks
//! across all three dataset topologies.

use microai::graph::ir::LayerKind;
use microai::graph::{deploy_pipeline, resnet_v1_6_shapes, Graph};
use microai::nn::float_exec::{self, ActStats};
use microai::nn::{affine_exec, argmax, int_exec};
use microai::quant::{quantize, quantize_affine, QuantSpec};
use microai::util::prng::Pcg32;

fn randomize(g: &mut Graph, seed: u64, scale: f32) {
    let mut rng = Pcg32::seeded(seed);
    for n in g.nodes.iter_mut() {
        if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
            for v in w.data.iter_mut() {
                *v = rng.normal() * scale;
            }
            for v in b.data.iter_mut() {
                *v = rng.normal() * 0.05;
            }
        }
    }
}

fn dataset_topologies() -> Vec<(Graph, usize)> {
    vec![
        (resnet_v1_6_shapes("har", 1, &[128, 9], 6, 8), 128 * 9),
        (resnet_v1_6_shapes("smnist", 1, &[39, 13], 10, 8), 39 * 13),
        (resnet_v1_6_shapes("gtsrb", 2, &[32, 32, 3], 43, 4), 32 * 32 * 3),
    ]
}

#[test]
fn int16_tracks_float_on_all_topologies() {
    // The paper's central int16 claim on all three dataset shapes:
    // per-layer int16 PTQ preserves the float argmax.
    for (mut g, ex_len) in dataset_topologies() {
        randomize(&mut g, 42, 0.35);
        let g = deploy_pipeline(&g);
        let mut rng = Pcg32::seeded(1);
        let inputs: Vec<Vec<f32>> =
            (0..6).map(|_| (0..ex_len).map(|_| rng.normal()).collect()).collect();
        let mut stats = ActStats::new(g.nodes.len());
        for x in &inputs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let qg = quantize(&g, &stats, QuantSpec::int16_per_layer());
        for x in &inputs {
            let fl = float_exec::run(&g, x, None);
            let il = int_exec::run(&qg, x);
            assert_eq!(argmax(&fl), argmax(&il), "graph {}", g.name);
        }
    }
}

#[test]
fn quantization_error_ordering_int8_int9_int16() {
    // Monotone refinement: total |logit error| shrinks with width.
    let mut g = resnet_v1_6_shapes("har", 1, &[64, 4], 5, 8);
    let ex_len = 64 * 4;
    randomize(&mut g, 7, 0.4);
    let g = deploy_pipeline(&g);
    let mut rng = Pcg32::seeded(2);
    let inputs: Vec<Vec<f32>> =
        (0..10).map(|_| (0..ex_len).map(|_| rng.normal()).collect()).collect();
    let mut stats = ActStats::new(g.nodes.len());
    for x in &inputs {
        float_exec::run(&g, x, Some(&mut stats));
    }
    let mut errs = Vec::new();
    for spec in [
        QuantSpec::int8_per_layer(),
        QuantSpec::int9_per_layer(),
        QuantSpec::int16_per_layer(),
    ] {
        let qg = quantize(&g, &stats, spec);
        let mut e = 0.0f64;
        for x in &inputs {
            let fl = float_exec::run(&g, x, None);
            for (u, v) in fl.iter().zip(int_exec::run(&qg, x)) {
                e += ((u - v) as f64).abs();
            }
        }
        errs.push(e);
    }
    assert!(errs[1] < errs[0], "int9 {} !< int8 {}", errs[1], errs[0]);
    assert!(errs[2] < errs[1], "int16 {} !< int9 {}", errs[2], errs[1]);
}

#[test]
fn synthetic_datasets_are_learnable_by_nearest_centroid() {
    // A sanity floor: the synthetic generators carry enough class signal
    // that a nearest-centroid classifier beats chance by a wide margin —
    // guaranteeing the CNN accuracy experiments are meaningful.
    for name in ["har", "smnist", "gtsrb"] {
        let d = microai::datasets::load(name, 9).unwrap();
        let l = d.example_len();
        let mut centroids = vec![vec![0.0f32; l]; d.classes];
        let mut counts = vec![0usize; d.classes];
        for i in 0..d.n_train() {
            let y = d.train_y[i] as usize;
            for (j, &v) in d.train_example(i).iter().enumerate() {
                centroids[y][j] += v;
            }
            counts[y] += 1;
        }
        for (c, &n) in centroids.iter_mut().zip(&counts) {
            for v in c.iter_mut() {
                *v /= n as f32;
            }
        }
        let mut correct = 0usize;
        for i in 0..d.n_test() {
            let x = d.test_example(i);
            let mut best = (f32::INFINITY, 0usize);
            for (k, c) in centroids.iter().enumerate() {
                let dist: f32 = x.iter().zip(c).map(|(a, b)| (a - b) * (a - b)).sum();
                if dist < best.0 {
                    best = (dist, k);
                }
            }
            if best.1 as i32 == d.test_y[i] {
                correct += 1;
            }
        }
        let acc = correct as f64 / d.n_test() as f64;
        let chance = 1.0 / d.classes as f64;
        assert!(acc > 3.0 * chance, "{name}: centroid acc {acc} vs chance {chance}");
    }
}

#[test]
fn affine_engine_handles_1d_topologies() {
    for (mut g, ex_len) in dataset_topologies().into_iter().take(2) {
        randomize(&mut g, 13, 0.3);
        let g = deploy_pipeline(&g);
        let mut rng = Pcg32::seeded(3);
        let inputs: Vec<Vec<f32>> =
            (0..4).map(|_| (0..ex_len).map(|_| rng.normal()).collect()).collect();
        let mut stats = ActStats::new(g.nodes.len());
        for x in &inputs {
            float_exec::run(&g, x, Some(&mut stats));
        }
        let aq = quantize_affine(&g, &stats);
        for x in &inputs {
            let out = affine_exec::run(&aq, x);
            assert!(out.iter().all(|v| v.is_finite()));
            assert_eq!(out.len(), g.nodes[g.output_id()].out_shape[0]);
        }
    }
}

#[test]
fn ram_allocation_matches_paper_scaling() {
    // §7: "the RAM usage ... is also reduced" — 2x/4x for int16/int8.
    use microai::allocator::{allocate, check_no_conflict};
    let g = deploy_pipeline(&resnet_v1_6_shapes("har", 1, &[128, 9], 6, 32));
    let a = allocate(&g);
    let f32_ram = a.ram_bytes(4);
    assert_eq!(a.ram_bytes(2) * 2, f32_ram);
    assert_eq!(a.ram_bytes(1) * 4, f32_ram);
    check_no_conflict(&g, &a).unwrap();
}

#[test]
fn deployment_passes_preserve_int_semantics_inputs() {
    // Quantizing the fused vs unfused graph yields close logits: the
    // passes commute with quantization up to fusion rounding.
    let mut g = resnet_v1_6_shapes("har", 1, &[64, 4], 5, 8);
    randomize(&mut g, 21, 0.35);
    let fused = deploy_pipeline(&g);
    let ex_len = 64 * 4;
    let mut rng = Pcg32::seeded(4);
    let inputs: Vec<Vec<f32>> =
        (0..6).map(|_| (0..ex_len).map(|_| rng.normal()).collect()).collect();
    let mut stats = ActStats::new(fused.nodes.len());
    for x in &inputs {
        float_exec::run(&fused, x, Some(&mut stats));
    }
    let qg = quantize(&fused, &stats, QuantSpec::int16_per_layer());
    for x in &inputs {
        let fl = float_exec::run(&g, x, None); // unfused float
        let il = int_exec::run(&qg, x); // fused int16
        assert_eq!(argmax(&fl), argmax(&il));
    }
}
