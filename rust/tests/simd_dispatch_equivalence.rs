//! ISSUE 10 pin: runtime kernel dispatch is behavior-preserving at the
//! SESSION level. For every backend a forced-scalar session and an
//! auto-dispatched session (whatever `nn::simd::detected()` resolves to
//! on this host) serve the same examples at threads {1, 4} and batch
//! {1, 8}; the integer engines (int8 / int16 fixed-point and affine)
//! must produce BIT-IDENTICAL logits — the kernel-set contract in
//! DESIGN.md §13 — and float32 must agree within the session's 1e-4
//! relative budget (AVX2+FMA contracts mul+add to one rounding, which
//! legitimately moves f32 bits; on non-AVX2 hosts both sessions run the
//! scalar set and the comparison degenerates to scalar-vs-scalar, which
//! keeps the suite green on every architecture).
//!
//! `SessionMeta::kernel` attributability rides along: the forced session
//! must report "scalar" and the auto session must report the detected
//! set, so a logged serving fleet can always tell which microkernels
//! produced an answer.

use std::sync::Arc;

use microai::graph::ir::LayerKind;
use microai::graph::{deploy_pipeline, resnet_v1_6_shapes, Graph};
use microai::nn::float_exec::ActStats;
use microai::nn::{simd, Session, SessionBuilder};
use microai::quant::{quantize, quantize_affine, QuantSpec};
use microai::util::prng::Pcg32;

const THREADS: [usize; 2] = [1, 4];
/// 1 pins the single-example fast path; 8 pins the batch-folded GEMMs
/// (examples stacked into M change the partitioning the kernels see).
const BATCHES: [usize; 2] = [1, 8];
/// Same relative budget the float session tests already grant the packed
/// path; FMA reassociation stays comfortably inside it (DESIGN.md §13).
const F32_TOL: f32 = 1e-4;

fn fixture_graph(dims: usize, shape: &[usize], classes: usize, filters: usize, seed: u64) -> Graph {
    let mut g = resnet_v1_6_shapes("fix", dims, shape, classes, filters);
    let mut rng = Pcg32::seeded(seed);
    for n in g.nodes.iter_mut() {
        if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
            for v in w.data.iter_mut() {
                *v = rng.normal() * 0.35;
            }
            for v in b.data.iter_mut() {
                *v = rng.normal() * 0.05;
            }
        }
    }
    deploy_pipeline(&g)
}

fn fixture_inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| (0..len).map(|_| rng.normal()).collect()).collect()
}

fn calibrate(g: &Graph, inputs: &[Vec<f32>]) -> ActStats {
    let mut sess = SessionBuilder::float32(g.clone()).build();
    let mut stats = ActStats::new(g.nodes.len());
    for x in inputs {
        assert!(sess.calibrate(x, &mut stats));
    }
    stats
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The property itself: a forced-scalar session and an auto-dispatched
/// session of the same backend serve identical batches; `exact` demands
/// bit-identical logits (integer engines), otherwise the 1e-4 relative
/// budget applies (float32 under FMA contraction).
fn pin_pair(mk: impl Fn(bool, usize) -> Session, pool: &[Vec<f32>], exact: bool, label: &str) {
    for &t in &THREADS {
        let mut scalar = mk(true, t);
        let mut auto = mk(false, t);
        assert_eq!(
            scalar.meta().kernel,
            "scalar",
            "{label} t={t}: forced-scalar session must report the scalar set"
        );
        assert_eq!(
            auto.meta().kernel,
            simd::detected().name,
            "{label} t={t}: auto session must report the detected set"
        );
        for &n in &BATCHES {
            // Cycle the example pool so n can exceed its size.
            let flat: Vec<f32> = (0..n).flat_map(|i| pool[i % pool.len()].clone()).collect();
            let s = scalar.run_batch(&flat);
            let a = auto.run_batch(&flat);
            assert_eq!(s.len(), a.len(), "{label} t={t} n={n}: logit count diverges");
            if exact {
                assert_eq!(
                    bits(&s),
                    bits(&a),
                    "{label} t={t} n={n}: integer logits must be bit-identical across \
                     kernel sets (dispatched: {})",
                    simd::detected().name
                );
            } else {
                for (i, (x, y)) in s.iter().zip(a.iter()).enumerate() {
                    let tol = F32_TOL.max(x.abs() * F32_TOL);
                    assert!(
                        (x - y).abs() <= tol,
                        "{label} t={t} n={n} logit {i}: {x} vs {y} exceeds the {F32_TOL} \
                         relative budget (dispatched: {})",
                        simd::detected().name
                    );
                }
            }
        }
    }
}

/// All four engine/width arms over one deployed graph, `max_batch(8)`.
fn pin_all_backends(g: &Graph, pool: &[Vec<f32>]) {
    let stats = calibrate(g, pool);
    let q16 = Arc::new(quantize(g, &stats, QuantSpec::int16_per_layer()));
    let q8 = Arc::new(quantize(g, &stats, QuantSpec::int8_per_layer()));
    let aq = Arc::new(quantize_affine(g, &stats));

    pin_pair(
        |fs, t| {
            SessionBuilder::float32(g.clone())
                .threads(t)
                .max_batch(8)
                .force_scalar_kernels(fs)
                .build()
        },
        pool,
        false,
        "float32",
    );
    pin_pair(
        |fs, t| {
            SessionBuilder::fixed_qmn(q16.clone())
                .threads(t)
                .max_batch(8)
                .force_scalar_kernels(fs)
                .build()
        },
        pool,
        true,
        "int16",
    );
    pin_pair(
        |fs, t| {
            SessionBuilder::fixed_qmn(q8.clone())
                .threads(t)
                .max_batch(8)
                .force_scalar_kernels(fs)
                .build()
        },
        pool,
        true,
        "int8",
    );
    pin_pair(
        |fs, t| {
            SessionBuilder::affine_i8(aq.clone())
                .threads(t)
                .max_batch(8)
                .force_scalar_kernels(fs)
                .build()
        },
        pool,
        true,
        "affine",
    );
}

#[test]
fn dispatch_equivalent_resnet_1d_har_shaped() {
    // k=3 convs, 1×1 shortcut convs (folded at batch 8), dense head.
    let g = fixture_graph(1, &[64, 6], 5, 8, 42);
    let pool = fixture_inputs(16, 64 * 6, 7);
    pin_all_backends(&g, &pool);
}

#[test]
fn dispatch_equivalent_resnet_1d_smnist_shaped() {
    // Different channel/class mix so tail geometry (n % NR, k odd) hits
    // different cases than the HAR fixture.
    let g = fixture_graph(1, &[39, 13], 10, 8, 43);
    let pool = fixture_inputs(12, 39 * 13, 8);
    pin_all_backends(&g, &pool);
}

#[test]
fn dispatch_equivalent_resnet_2d_gtsrb_shaped() {
    // conv2d topology: the 2-D im2col path feeds the kernels per row.
    let g = fixture_graph(2, &[12, 12, 3], 4, 4, 9);
    let pool = fixture_inputs(8, 12 * 12 * 3, 11);
    pin_all_backends(&g, &pool);
}

/// Randomized 2-block transformer (embedding → [LN → MHSA → add → LN →
/// FFN → add] ×2 → GAP → dense → softmax): pins the packed-attention
/// projections' dispatch alongside conv/dense.
fn transformer_fixture(seed: u64) -> (Graph, u32) {
    const VOCAB: u32 = 20;
    let mut g = microai::graph::build::transformer("txfix", 12, VOCAB as usize, 16, 2, 2, 2, 5);
    let mut rng = Pcg32::seeded(seed);
    for n in g.nodes.iter_mut() {
        match &mut n.kind {
            LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } => {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.3;
                }
                for v in b.data.iter_mut() {
                    *v = rng.normal() * 0.05;
                }
            }
            LayerKind::Embedding { w } => {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.5;
                }
            }
            LayerKind::LayerNorm { gamma, beta, .. } => {
                for v in gamma.iter_mut() {
                    *v = 1.0 + rng.normal() * 0.2;
                }
                for v in beta.iter_mut() {
                    *v = rng.normal() * 0.1;
                }
            }
            LayerKind::SelfAttention { w, .. } => {
                for t in [&mut w.wq, &mut w.wk, &mut w.wv, &mut w.wo] {
                    for v in t.data.iter_mut() {
                        *v = rng.normal() * 0.3;
                    }
                }
                for t in [&mut w.bq, &mut w.bk, &mut w.bv, &mut w.bo] {
                    for v in t.data.iter_mut() {
                        *v = rng.normal() * 0.05;
                    }
                }
            }
            _ => {}
        }
    }
    (deploy_pipeline(&g), VOCAB)
}

#[test]
fn dispatch_equivalent_transformer() {
    let (g, vocab) = transformer_fixture(91);
    let seq: usize = g.input_shape.iter().product();
    let mut rng = Pcg32::seeded(92);
    let pool: Vec<Vec<f32>> =
        (0..8).map(|_| (0..seq).map(|_| rng.below(vocab) as f32).collect()).collect();
    pin_all_backends(&g, &pool);
}
