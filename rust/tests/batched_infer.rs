//! ISSUE 8 property pin: batch-folded `Session::infer` is BIT-EXACT
//! against serving the same examples one at a time — on every backend,
//! at widths {8, 16}, batch sizes {1, 2, 7, 64}, and threads {1, 4} —
//! including a non-contiguous strided `Batch` view and the transformer's
//! unfoldable layers (embedding → layernorm → attention → softmax loop
//! per example inside the same plan).
//!
//! The fold argument (DESIGN.md §11): batched dense / 1×1-conv layers
//! stack examples into the GEMM M dimension, leaving the per-element
//! k-major accumulation order and fused epilogue untouched, so the
//! integer engines reproduce the serial bits and float32 is bitwise
//! identical; everything else loops per example through the exact code
//! the single-example path runs. These tests pin that claim instead of
//! trusting it.

use std::sync::Arc;

use microai::graph::ir::LayerKind;
use microai::graph::{deploy_pipeline, resnet_v1_6_shapes, Graph};
use microai::nn::float_exec::ActStats;
use microai::nn::{Batch, ForkOpts, Predictions, Session, SessionBuilder};
use microai::quant::{quantize, quantize_affine, QuantSpec};
use microai::util::prng::Pcg32;

/// 64 exceeds the arenas' `max_batch(8)`, so it pins the chunked
/// micro-batch loop; 7 pins a partial final fold; 1 pins the fast path.
const BATCHES: [usize; 4] = [1, 2, 7, 64];
const THREADS: [usize; 2] = [1, 4];

fn fixture_graph(dims: usize, shape: &[usize], classes: usize, filters: usize, seed: u64) -> Graph {
    let mut g = resnet_v1_6_shapes("fix", dims, shape, classes, filters);
    let mut rng = Pcg32::seeded(seed);
    for n in g.nodes.iter_mut() {
        if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
            for v in w.data.iter_mut() {
                *v = rng.normal() * 0.35;
            }
            for v in b.data.iter_mut() {
                *v = rng.normal() * 0.05;
            }
        }
    }
    deploy_pipeline(&g)
}

fn fixture_inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| (0..len).map(|_| rng.normal()).collect()).collect()
}

fn calibrate(g: &Graph, inputs: &[Vec<f32>]) -> ActStats {
    let mut sess = SessionBuilder::float32(g.clone()).build();
    let mut stats = ActStats::new(g.nodes.len());
    for x in inputs {
        assert!(sess.calibrate(x, &mut stats));
    }
    stats
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The property itself: for every batch size, the folded batch produces
/// the same LOGIT BITS as running each example alone, and `infer`'s
/// predictions agree per example (class + confidence bits).
fn pin_batched_vs_singles(sess: &mut Session, pool: &[Vec<f32>], label: &str) {
    let ilen = sess.input_len();
    for &n in &BATCHES {
        // Cycle the example pool so n can exceed its size.
        let flat: Vec<f32> = (0..n).flat_map(|i| pool[i % pool.len()].clone()).collect();

        let mut singles: Vec<f32> = Vec::new();
        for ex in flat.chunks_exact(ilen) {
            singles.extend_from_slice(sess.run(ex));
        }
        let batched = sess.run_batch(&flat);
        assert_eq!(bits(&singles), bits(&batched), "{label} n={n}: batched logits diverge");

        let mut preds: Predictions = Vec::new();
        sess.infer(&Batch::contiguous(&flat, ilen), &mut preds);
        assert_eq!(preds.len(), n, "{label} n={n}: one prediction per example");
        let mut one: Predictions = Vec::new();
        for (e, ex) in flat.chunks_exact(ilen).enumerate() {
            one.clear();
            sess.infer(&Batch::single(ex), &mut one);
            assert_eq!(
                (one[0].class, one[0].confidence.to_bits()),
                (preds[e].class, preds[e].confidence.to_bits()),
                "{label} n={n} ex={e}: prediction diverges"
            );
        }
    }
}

/// All four engine/width arms over one deployed graph, `max_batch(8)`.
fn pin_all_backends(g: &Graph, pool: &[Vec<f32>]) {
    let stats = calibrate(g, pool);
    let q16 = Arc::new(quantize(g, &stats, QuantSpec::int16_per_layer()));
    let q8 = Arc::new(quantize(g, &stats, QuantSpec::int8_per_layer()));
    let aq = Arc::new(quantize_affine(g, &stats));

    for &t in &THREADS {
        let mut arms = vec![
            ("float32", SessionBuilder::float32(g.clone()).threads(t).max_batch(8).build()),
            ("int16", SessionBuilder::fixed_qmn(q16.clone()).threads(t).max_batch(8).build()),
            ("int8", SessionBuilder::fixed_qmn(q8.clone()).threads(t).max_batch(8).build()),
            ("affine", SessionBuilder::affine_i8(aq.clone()).threads(t).max_batch(8).build()),
        ];
        for (name, sess) in arms.iter_mut() {
            // ISSUE 9 satellite: every built session's memory plan must
            // re-prove under the trusted byte-range checker, and the
            // coalesced arena must never exceed the §5.7 pooled baseline.
            let alloc = &sess.plan().alloc;
            microai::allocator::check_no_conflict(g, alloc)
                .unwrap_or_else(|e| panic!("{name} t={t}: shipped plan refused: {e}"));
            assert!(
                alloc.arena_elems <= alloc.pooled_elems,
                "{name} t={t}: planned arena {} exceeds pooled baseline {}",
                alloc.arena_elems,
                alloc.pooled_elems
            );
            pin_batched_vs_singles(sess, pool, &format!("{name} t={t}"));
        }
    }
}

#[test]
fn batched_infer_bit_exact_resnet_1d() {
    // HAR-shaped: dense head folds, k=3 convs loop, 1×1 shortcut convs fold.
    let g = fixture_graph(1, &[64, 6], 5, 8, 42);
    let pool = fixture_inputs(16, 64 * 6, 7);
    pin_all_backends(&g, &pool);
}

#[test]
fn batched_infer_bit_exact_resnet_2d() {
    // conv2d topology: the 2-D im2col path folds only its 1×1 layers.
    let g = fixture_graph(2, &[12, 12, 3], 4, 4, 9);
    let pool = fixture_inputs(8, 12 * 12 * 3, 11);
    pin_all_backends(&g, &pool);
}

#[test]
fn strided_batch_view_matches_contiguous() {
    // Records longer than an example (payload + trailing telemetry
    // fields): the zero-copy strided view must classify identically to a
    // contiguous copy of the payloads — the executor falls back to its
    // per-example gather, which must not change a single bit.
    let g = fixture_graph(1, &[64, 6], 5, 8, 17);
    let pool = fixture_inputs(8, 64 * 6, 18);
    let stats = calibrate(&g, &pool);
    let q8 = Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()));

    let ilen = 64 * 6;
    let stride = ilen + 5;
    let n = 7usize;
    let mut rng = Pcg32::seeded(19);
    let records: Vec<f32> = (0..(n - 1) * stride + ilen).map(|_| rng.normal()).collect();
    let flat: Vec<f32> = (0..n)
        .flat_map(|e| records[e * stride..e * stride + ilen].to_vec())
        .collect();

    let mut arms = vec![
        SessionBuilder::float32(g.clone()).max_batch(4).build(),
        SessionBuilder::fixed_qmn(q8).threads(4).max_batch(4).build(),
    ];
    for sess in arms.iter_mut() {
        let mut strided: Predictions = Vec::new();
        sess.infer(&Batch::strided(&records, n, ilen, stride), &mut strided);
        let mut contiguous: Predictions = Vec::new();
        sess.infer(&Batch::contiguous(&flat, ilen), &mut contiguous);
        assert_eq!(strided.len(), n);
        for (a, b) in strided.iter().zip(&contiguous) {
            assert_eq!(
                (a.class, a.confidence.to_bits()),
                (b.class, b.confidence.to_bits()),
                "{}: strided view diverges from contiguous copy",
                sess.meta().backend
            );
        }
    }
}

/// Randomized 2-block transformer: embedding → [LN → MHSA → add → LN →
/// FFN → add] ×2 → GAP → dense → softmax. Every block layer except the
/// FFN 1×1s is unfoldable, so this pins the per-example loop inside the
/// batched plan (and the fold/loop interleaving around it).
fn transformer_fixture(seed: u64) -> (Graph, u32) {
    const VOCAB: u32 = 20;
    let mut g = microai::graph::build::transformer("txfix", 12, VOCAB as usize, 16, 2, 2, 2, 5);
    let mut rng = Pcg32::seeded(seed);
    for n in g.nodes.iter_mut() {
        match &mut n.kind {
            LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } => {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.3;
                }
                for v in b.data.iter_mut() {
                    *v = rng.normal() * 0.05;
                }
            }
            LayerKind::Embedding { w } => {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.5;
                }
            }
            LayerKind::LayerNorm { gamma, beta, .. } => {
                for v in gamma.iter_mut() {
                    *v = 1.0 + rng.normal() * 0.2;
                }
                for v in beta.iter_mut() {
                    *v = rng.normal() * 0.1;
                }
            }
            LayerKind::SelfAttention { w, .. } => {
                for t in [&mut w.wq, &mut w.wk, &mut w.wv, &mut w.wo] {
                    for v in t.data.iter_mut() {
                        *v = rng.normal() * 0.3;
                    }
                }
                for t in [&mut w.bq, &mut w.bk, &mut w.bv, &mut w.bo] {
                    for v in t.data.iter_mut() {
                        *v = rng.normal() * 0.05;
                    }
                }
            }
            _ => {}
        }
    }
    (deploy_pipeline(&g), VOCAB)
}

#[test]
fn batched_infer_bit_exact_transformer_unfoldable_layers() {
    let (g, vocab) = transformer_fixture(91);
    let seq: usize = g.input_shape.iter().product();
    let mut rng = Pcg32::seeded(92);
    let pool: Vec<Vec<f32>> =
        (0..8).map(|_| (0..seq).map(|_| rng.below(vocab) as f32).collect()).collect();
    pin_all_backends(&g, &pool);
}

#[test]
fn forked_worker_with_batch_capacity_matches_template() {
    // ISSUE 8 satellite: `ForkOpts` sizes the worker's arena for folded
    // micro-batches; its batched answers must match the template serving
    // one example at a time from its own (max_batch = 1) arena.
    let g = fixture_graph(1, &[64, 6], 5, 8, 61);
    let pool = fixture_inputs(8, 64 * 6, 62);
    let stats = calibrate(&g, &pool);
    let q8 = Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()));

    let mut root = SessionBuilder::fixed_qmn(q8).build();
    assert_eq!(root.meta().max_batch, 1);
    let mut worker = root.fork_with(ForkOpts::inherit().threads(4).max_batch(4));
    assert_eq!(worker.meta().max_batch, 4);

    let flat: Vec<f32> = pool.iter().flatten().copied().collect();
    let mut singles: Vec<f32> = Vec::new();
    for x in &pool {
        singles.extend_from_slice(root.run(x));
    }
    assert_eq!(bits(&singles), bits(&worker.run_batch(&flat)));

    // Degenerate capacities are refused up front, not deep in the
    // allocator.
    assert!(root.try_fork_with(ForkOpts::inherit().max_batch(0)).is_err());
}
