//! Cross-backend session equivalence: the same deployed graph served
//! through Float32 / FixedQmn(int16) / FixedQmn(int8) / AffineI8 sessions
//! must agree on argmax (within the tolerance each scheme is known to
//! hold, §6 / Appendix B), and the unified API must match the legacy free
//! functions bit-for-bit while reusing its arena.

use std::sync::Arc;

use microai::graph::ir::LayerKind;
use microai::graph::{deploy_pipeline, resnet_v1_6_shapes, Graph};
use microai::nn::float_exec::ActStats;
use microai::nn::{argmax, InferenceBackend, SessionBuilder};
use microai::quant::{quantize, quantize_affine, QuantSpec};
use microai::util::prng::Pcg32;

fn fixture_graph(dims: usize, shape: &[usize], classes: usize, filters: usize, seed: u64) -> Graph {
    let mut g = resnet_v1_6_shapes("fix", dims, shape, classes, filters);
    let mut rng = Pcg32::seeded(seed);
    for n in g.nodes.iter_mut() {
        if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut n.kind {
            for v in w.data.iter_mut() {
                *v = rng.normal() * 0.35;
            }
            for v in b.data.iter_mut() {
                *v = rng.normal() * 0.05;
            }
        }
    }
    deploy_pipeline(&g)
}

fn fixture_inputs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| (0..len).map(|_| rng.normal()).collect()).collect()
}

fn calibrate(g: &Graph, inputs: &[Vec<f32>]) -> ActStats {
    let mut sess = SessionBuilder::float32(g.clone()).build();
    let mut stats = ActStats::new(g.nodes.len());
    for x in inputs {
        assert!(sess.calibrate(x, &mut stats));
    }
    stats
}

#[test]
fn cross_backend_argmax_agreement_on_fixture_inputs() {
    // HAR-shaped 1-D fixture; 16 inputs through all four backends.
    let g = fixture_graph(1, &[64, 6], 5, 8, 42);
    let inputs = fixture_inputs(16, 64 * 6, 7);
    let stats = calibrate(&g, &inputs);

    let q16 = Arc::new(quantize(&g, &stats, QuantSpec::int16_per_layer()));
    let q8 = Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()));
    let aq = Arc::new(quantize_affine(&g, &stats));

    let mut s_float = SessionBuilder::float32(g.clone()).build();
    let mut s_16 = SessionBuilder::fixed_qmn(q16).build();
    let mut s_8 = SessionBuilder::fixed_qmn(q8).build();
    let mut s_aff = SessionBuilder::affine_i8(aq).build();

    let (mut agree16, mut agree8, mut agree_aff) = (0usize, 0usize, 0usize);
    for x in &inputs {
        let reference = argmax(&s_float.run(x).to_vec());
        agree16 += (argmax(s_16.run(x)) == reference) as usize;
        agree8 += (argmax(s_8.run(x)) == reference) as usize;
        agree_aff += (argmax(s_aff.run(x)) == reference) as usize;
    }
    // §6: int16 tracks float essentially everywhere.
    assert_eq!(agree16, inputs.len(), "int16 argmax agreement {agree16}/{}", inputs.len());
    // 8-bit schemes may drop a little accuracy (PTQ without QAT).
    assert!(agree8 * 4 >= inputs.len() * 3, "int8 agreement {agree8}/{}", inputs.len());
    assert!(agree_aff * 4 >= inputs.len() * 3, "affine agreement {agree_aff}/{}", inputs.len());
}

#[test]
fn cross_backend_agreement_2d_topology() {
    let g = fixture_graph(2, &[12, 12, 3], 4, 4, 9);
    let inputs = fixture_inputs(8, 12 * 12 * 3, 11);
    let stats = calibrate(&g, &inputs);
    let q16 = Arc::new(quantize(&g, &stats, QuantSpec::int16_per_layer()));

    let mut s_float = SessionBuilder::float32(g.clone()).build();
    let mut s_16 = SessionBuilder::fixed_qmn(q16).build();
    for x in &inputs {
        let a = argmax(&s_float.run(x).to_vec());
        let b = argmax(s_16.run(x));
        assert_eq!(a, b);
    }
}

#[test]
fn cross_backend_agreement_gtsrb_conv2d_topology() {
    // GTSRB-shaped (32x32x3, 43 classes) conv2d-heavy graph end to end:
    // all four backends through the Session API, the conv2d GEMM path vs
    // the legacy free functions bit-for-bit, and the arena (incl. the new
    // im2col scratch) staying put across requests.
    let g = fixture_graph(2, &[32, 32, 3], 43, 8, 31);
    let inputs = fixture_inputs(6, 32 * 32 * 3, 33);
    let stats = calibrate(&g, &inputs);
    let q16 = Arc::new(quantize(&g, &stats, QuantSpec::int16_per_layer()));
    let q8 = Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()));
    let aq = Arc::new(quantize_affine(&g, &stats));

    let mut s_float = SessionBuilder::float32(g.clone()).build();
    let mut s_16 = SessionBuilder::fixed_qmn(q16.clone()).build();
    let mut s_8 = SessionBuilder::fixed_qmn(q8.clone()).build();
    let mut s_aff = SessionBuilder::affine_i8(aq.clone()).build();

    // The conv2d layers are big enough to engage the blocked GEMM path;
    // its scratch must come from the preallocated arena.
    s_16.run(&inputs[0]);
    let ptrs = s_16.arena().buffer_ptrs();

    let (mut agree16, mut agree8, mut agree_aff) = (0usize, 0usize, 0usize);
    for x in &inputs {
        let reference = argmax(&s_float.run(x).to_vec());
        agree16 += (argmax(s_16.run(x)) == reference) as usize;
        agree8 += (argmax(s_8.run(x)) == reference) as usize;
        agree_aff += (argmax(s_aff.run(x)) == reference) as usize;

        // Sessions and legacy free functions agree: bit-for-bit for the
        // integer engines (the prepacked and per-call paths are both
        // pinned bit-exact vs the refs), 2-D included; float within the
        // 1e-4 fused-reorder budget (sessions run the prepacked blocked
        // kernel on every shape, the legacy path falls back to the
        // reference on tiny layers).
        assert_eq!(microai::nn::int_exec::run(&q16, x), s_16.run(x).to_vec());
        assert_eq!(microai::nn::affine_exec::run(&aq, x), s_aff.run(x).to_vec());
        let legacy_f = microai::nn::float_exec::run(&g, x, None);
        for (a, b) in legacy_f.iter().zip(s_float.run(x)) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
    // 43 random-weight classes sit near argmax ties, so the statistical
    // thresholds are deliberately loose — the bit-exactness asserts above
    // are the real regression catchers.
    assert!(agree16 + 1 >= inputs.len(), "int16 argmax agreement {agree16}/{}", inputs.len());
    assert!(agree8 * 3 >= inputs.len(), "int8 agreement {agree8}/{}", inputs.len());
    assert!(agree_aff * 3 >= inputs.len(), "affine agreement {agree_aff}/{}", inputs.len());
    assert_eq!(ptrs, s_16.arena().buffer_ptrs(), "conv2d GEMM scratch reallocated");
}

#[test]
fn threaded_sessions_bit_exact_with_stable_per_thread_scratch() {
    // ISSUE 4: the intra-op GEMM pool must (a) reproduce the serial bits
    // on every backend at threads ∈ {2, 4} over a conv2d-heavy GTSRB
    // fixture, and (b) keep ALL per-thread scratch slab pointers stable
    // across requests at threads = 4 — an undersized slab on any worker
    // would reallocate and show up in `Arena::buffer_ptrs`.
    let g = fixture_graph(2, &[32, 32, 3], 43, 8, 51);
    let inputs = fixture_inputs(5, 32 * 32 * 3, 52);
    let stats = calibrate(&g, &inputs);
    let q16 = Arc::new(quantize(&g, &stats, QuantSpec::int16_per_layer()));
    let q8 = Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()));
    let aq = Arc::new(quantize_affine(&g, &stats));

    let mut serial_f = SessionBuilder::float32(g.clone()).build();
    let mut serial_16 = SessionBuilder::fixed_qmn(q16.clone()).build();
    let mut serial_8 = SessionBuilder::fixed_qmn(q8.clone()).build();
    let mut serial_aff = SessionBuilder::affine_i8(aq.clone()).build();

    for threads in [2usize, 4] {
        let mut t_f = SessionBuilder::float32(g.clone()).threads(threads).build();
        let mut t_16 = SessionBuilder::fixed_qmn(q16.clone()).threads(threads).build();
        let mut t_8 = SessionBuilder::fixed_qmn(q8.clone()).threads(threads).build();
        let mut t_aff = SessionBuilder::affine_i8(aq.clone()).threads(threads).build();
        for x in &inputs {
            // Integer backends: bit-identical. Float: the schedule is
            // order-identical, so exact equality holds here too.
            assert_eq!(serial_16.run(x).to_vec(), t_16.run(x).to_vec(), "int16 t={threads}");
            assert_eq!(serial_8.run(x).to_vec(), t_8.run(x).to_vec(), "int8 t={threads}");
            assert_eq!(serial_aff.run(x).to_vec(), t_aff.run(x).to_vec(), "affine t={threads}");
            assert_eq!(serial_f.run(x).to_vec(), t_f.run(x).to_vec(), "float t={threads}");
        }
    }

    // Scratch-pointer stability at threads = 4: one slab per thread, all
    // exposed by buffer_ptrs, none reallocated across repeated runs.
    let mut s4 = SessionBuilder::fixed_qmn(q16).threads(4).build();
    assert_eq!(s4.arena().intra_op_threads(), 4);
    s4.run(&inputs[0]);
    let ptrs = s4.arena().buffer_ptrs();
    // 4 i32 slabs beyond the serial arena's single slab.
    assert_eq!(ptrs.len(), serial_16.arena().buffer_ptrs().len() + 3);
    for x in &inputs {
        for _ in 0..2 {
            s4.run(x);
        }
    }
    assert_eq!(ptrs, s4.arena().buffer_ptrs(), "per-thread GEMM scratch reallocated");
}

#[test]
fn odd_length_har_window_keeps_remainder() {
    // Regression for the silent pooling truncation: a 129-sample UCI-HAR
    // style window used to lose its last sample at every pool (floor);
    // SAME-style windows keep it, and every backend agrees on the shapes
    // and the legacy/Session bit-exactness.
    let g = fixture_graph(1, &[129, 9], 6, 8, 77);
    let pool = g
        .nodes
        .iter()
        .find(|n| matches!(n.kind, LayerKind::MaxPool { .. }))
        .expect("resnet has a pool");
    assert_eq!(pool.out_shape[0], 65, "ceil(129/2) remainder window missing");

    let inputs = fixture_inputs(6, 129 * 9, 78);
    let stats = calibrate(&g, &inputs);
    let q16 = Arc::new(quantize(&g, &stats, QuantSpec::int16_per_layer()));
    let mut s_float = SessionBuilder::float32(g.clone()).build();
    let mut s_16 = SessionBuilder::fixed_qmn(q16.clone()).build();
    for x in &inputs {
        let a = argmax(&s_float.run(x).to_vec());
        assert_eq!(a, argmax(s_16.run(x)));
        assert_eq!(microai::nn::int_exec::run(&q16, x), s_16.run(x).to_vec());
    }
}

#[test]
fn sessions_match_legacy_free_functions() {
    // Integer engines: bit-for-bit (prepacked and per-call paths are
    // both property-pinned bit-exact against the reference kernels).
    // Float: within the 1e-4 fused-reorder budget — the prepacked
    // session runs the blocked kernel on every shape while the legacy
    // per-call path falls back to the naive reference on tiny layers.
    let g = fixture_graph(1, &[32, 3], 4, 8, 5);
    let inputs = fixture_inputs(6, 96, 6);
    let stats = calibrate(&g, &inputs);
    let q8 = quantize(&g, &stats, QuantSpec::int8_per_layer());
    let aq = quantize_affine(&g, &stats);

    let mut s_float = SessionBuilder::float32(g.clone()).build();
    let mut s_8 = SessionBuilder::fixed_qmn(q8.clone()).build();
    let mut s_aff = SessionBuilder::affine_i8(aq.clone()).build();
    for x in &inputs {
        let legacy_f = microai::nn::float_exec::run(&g, x, None);
        for (a, b) in legacy_f.iter().zip(s_float.run(x)) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
        assert_eq!(microai::nn::int_exec::run(&q8, x), s_8.run(x).to_vec());
        assert_eq!(microai::nn::affine_exec::run(&aq, x), s_aff.run(x).to_vec());
    }
}

#[test]
fn forked_sessions_alias_one_packed_weights_arena_with_stable_buffers() {
    // ISSUE 5 satellite: (a) every fork shares ONE prepacked weight
    // allocation (Arc pointer equality — weights are packed once at
    // build, never per fork, never per request), and (b) a forked
    // threads=4 session's arena buffers (incl. every per-thread scratch
    // slab) stay put across repeated runs.
    let g = fixture_graph(2, &[32, 32, 3], 43, 8, 61);
    let inputs = fixture_inputs(4, 32 * 32 * 3, 62);
    let stats = calibrate(&g, &inputs);
    let q8 = Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()));

    let root = SessionBuilder::fixed_qmn(q8).build();
    assert!(root.meta().packed_weight_bytes > 0, "fixed backend must prepack");
    // The deprecated wrapper must stay green (ISSUE 8 acceptance) and
    // mean exactly `ForkOpts::inherit().threads(4)`.
    #[allow(deprecated)]
    let mut w1 = root.fork_with_threads(4);
    let mut w2 = root.fork_with(microai::nn::ForkOpts::inherit().threads(4));
    assert!(
        Arc::ptr_eq(&root.plan().packed, &w1.plan().packed)
            && Arc::ptr_eq(&root.plan().packed, &w2.plan().packed),
        "forks must alias the template's PackedWeights allocation"
    );

    // Forked workers produce identical bits (shared packed weights) from
    // distinct arenas whose buffers never move across requests.
    w1.run(&inputs[0]);
    w2.run(&inputs[0]);
    let (p1, p2) = (w1.arena().buffer_ptrs(), w2.arena().buffer_ptrs());
    assert_ne!(p1, p2, "forks must not share activation arenas");
    for x in &inputs {
        for _ in 0..2 {
            assert_eq!(w1.run(x).to_vec(), w2.run(x).to_vec());
        }
    }
    assert_eq!(p1, w1.arena().buffer_ptrs(), "fork 1 arena reallocated");
    assert_eq!(p2, w2.arena().buffer_ptrs(), "fork 2 arena reallocated");
}

#[test]
fn session_arena_is_not_reallocated_across_requests() {
    let g = fixture_graph(1, &[64, 6], 5, 8, 3);
    let inputs = fixture_inputs(12, 64 * 6, 4);
    let stats = calibrate(&g, &inputs);
    let q8 = Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()));

    for mut sess in [
        SessionBuilder::float32(g.clone()).build(),
        SessionBuilder::fixed_qmn(q8).build(),
    ] {
        sess.run(&inputs[0]);
        let ptrs = sess.arena().buffer_ptrs();
        let bytes = sess.arena().host_bytes();
        for x in &inputs {
            sess.run(x);
        }
        let flat: Vec<f32> = inputs.iter().flatten().copied().collect();
        let batched = sess.run_batch(&flat);
        assert_eq!(batched.len(), inputs.len() * sess.output_len());
        assert_eq!(ptrs, sess.arena().buffer_ptrs(), "{}: arena reallocated", sess.meta().backend);
        assert_eq!(bytes, sess.arena().host_bytes());
        assert_eq!(sess.runs(), 1 + inputs.len() as u64 + inputs.len() as u64);
    }
}

/// Randomized 2-block transformer (ISSUE 6): embedding → [LN → MHSA →
/// add → LN → FFN → add] ×2 → GAP → dense → softmax, with the output
/// softmax kept through deployment (`strip_softmax = false`).
fn transformer_fixture(seed: u64) -> (Graph, u32) {
    const VOCAB: u32 = 20;
    let mut g = microai::graph::build::transformer("txfix", 12, VOCAB as usize, 16, 2, 2, 2, 5);
    let mut rng = Pcg32::seeded(seed);
    for n in g.nodes.iter_mut() {
        match &mut n.kind {
            LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } => {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.3;
                }
                for v in b.data.iter_mut() {
                    *v = rng.normal() * 0.05;
                }
            }
            LayerKind::Embedding { w } => {
                for v in w.data.iter_mut() {
                    *v = rng.normal() * 0.5;
                }
            }
            LayerKind::LayerNorm { gamma, beta, .. } => {
                for v in gamma.iter_mut() {
                    *v = 1.0 + rng.normal() * 0.2;
                }
                for v in beta.iter_mut() {
                    *v = rng.normal() * 0.1;
                }
            }
            LayerKind::SelfAttention { w, .. } => {
                for t in [&mut w.wq, &mut w.wk, &mut w.wv, &mut w.wo] {
                    for v in t.data.iter_mut() {
                        *v = rng.normal() * 0.3;
                    }
                }
                for t in [&mut w.bq, &mut w.bk, &mut w.bv, &mut w.bo] {
                    for v in t.data.iter_mut() {
                        *v = rng.normal() * 0.05;
                    }
                }
            }
            _ => {}
        }
    }
    (deploy_pipeline(&g), VOCAB)
}

fn token_inputs(n: usize, len: usize, vocab: u32, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seeded(seed);
    (0..n).map(|_| (0..len).map(|_| rng.below(vocab) as f32).collect()).collect()
}

#[test]
fn transformer_cross_backend_sessions_bit_exact_and_classifying() {
    // ISSUE 6 acceptance: the transformer classifies through ALL THREE
    // backends via the Session API, the integer sessions match the legacy
    // free functions bit-for-bit at threads ∈ {1, 4} (fused packed
    // attention vs the naive reference path), and float stays within the
    // 1e-4 fused-reorder budget.
    let (g, vocab) = transformer_fixture(91);
    let seq: usize = g.input_shape.iter().product();
    let inputs = token_inputs(8, seq, vocab, 92);
    let stats = calibrate(&g, &inputs);

    let q16 = Arc::new(quantize(&g, &stats, QuantSpec::int16_per_layer()));
    let q8 = Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()));
    let aq = Arc::new(quantize_affine(&g, &stats));

    for threads in [1usize, 4] {
        let mut s_f = SessionBuilder::float32(g.clone()).threads(threads).build();
        let mut s_16 = SessionBuilder::fixed_qmn(q16.clone()).threads(threads).build();
        let mut s_8 = SessionBuilder::fixed_qmn(q8.clone()).threads(threads).build();
        let mut s_aff = SessionBuilder::affine_i8(aq.clone()).threads(threads).build();

        let (mut agree16, mut agree8, mut agree_aff) = (0usize, 0usize, 0usize);
        for x in &inputs {
            // Bit-exactness against the legacy per-call reference engines:
            // the packed two-GEMM attention, LUT softmax, and layernorm
            // must reproduce the naive integer kernels exactly.
            assert_eq!(
                microai::nn::int_exec::run(&q16, x),
                s_16.run(x).to_vec(),
                "int16 attention t={threads}"
            );
            assert_eq!(
                microai::nn::int_exec::run(&q8, x),
                s_8.run(x).to_vec(),
                "int8 attention t={threads}"
            );
            assert_eq!(
                microai::nn::affine_exec::run(&aq, x),
                s_aff.run(x).to_vec(),
                "affine attention t={threads}"
            );
            let legacy_f = microai::nn::float_exec::run(&g, x, None);
            for (a, b) in legacy_f.iter().zip(s_f.run(x)) {
                assert!((a - b).abs() < 1e-4, "float attention t={threads}: {a} vs {b}");
            }

            let reference = argmax(&s_f.run(x).to_vec());
            agree16 += (argmax(s_16.run(x)) == reference) as usize;
            agree8 += (argmax(s_8.run(x)) == reference) as usize;
            agree_aff += (argmax(s_aff.run(x)) == reference) as usize;
        }
        // Post-softmax probabilities on a random-weight net sit closer to
        // uniform than resnet logits, so leave one tie's worth of slack on
        // int16 and be looser on the 8-bit schemes; the bit-exactness
        // asserts above are the real regression catchers.
        assert!(agree16 + 1 >= inputs.len(), "int16 argmax {agree16}/{}", inputs.len());
        assert!(agree8 * 2 >= inputs.len(), "int8 argmax {agree8}/{}", inputs.len());
        assert!(agree_aff * 2 >= inputs.len(), "affine argmax {agree_aff}/{}", inputs.len());
    }
}

#[test]
fn session_metadata_tracks_deployment_costs() {
    use microai::mcu::board::{NUCLEO_L452RE_P, SPARKFUN_EDGE};

    let g = fixture_graph(1, &[128, 9], 6, 16, 21);
    let inputs = fixture_inputs(4, 128 * 9, 22);
    let stats = calibrate(&g, &inputs);
    let q8 = Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()));
    let q16 = Arc::new(quantize(&g, &stats, QuantSpec::int16_per_layer()));

    let s8 = SessionBuilder::fixed_qmn(q8.clone()).board(&SPARKFUN_EDGE).build();
    let s16 = SessionBuilder::fixed_qmn(q16).board(&SPARKFUN_EDGE).build();
    let sf = SessionBuilder::float32(g.clone()).board(&SPARKFUN_EDGE).build();

    // §7: int16 always beats float32 on the MicroAI engine; int8 is the
    // cheapest; ROM ordering follows dtype width.
    let (m8, m16, mf) = (s8.meta(), s16.meta(), sf.meta());
    let lat = |m: &microai::nn::SessionMeta| m.device_latency_ms.unwrap();
    assert!(lat(m8) < lat(m16) && lat(m16) < lat(mf), "{} {} {}", lat(m8), lat(m16), lat(mf));
    assert!(m8.weight_bytes < m16.weight_bytes && m16.weight_bytes < mf.weight_bytes);
    assert!(m8.device_ram_bytes < m16.device_ram_bytes);
    assert_eq!(m16.device_ram_bytes * 2, mf.device_ram_bytes);

    // Energy scales with board power at equal cycle model: the SparkFun
    // Edge is the most efficient board (Fig 13).
    let s8n = SessionBuilder::fixed_qmn(q8).board(&NUCLEO_L452RE_P).build();
    assert!(
        s8.meta().device_energy_uwh.unwrap() < s8n.meta().device_energy_uwh.unwrap()
    );
}

#[test]
fn every_built_session_plan_passes_the_independent_checker() {
    // ISSUE 9 satellite: the planner (allocator::planner) is UNTRUSTED;
    // every session the builder admits must carry a plan the trusted
    // byte-range checker independently re-proves, and the coalesced
    // arena must never exceed the §5.7 pooled baseline it replaced.
    let (tg, vocab) = transformer_fixture(95);
    let seq: usize = tg.input_shape.iter().product();
    let fixtures: Vec<(Graph, Vec<Vec<f32>>)> = vec![
        (fixture_graph(1, &[64, 6], 5, 8, 93), fixture_inputs(6, 64 * 6, 94)),
        (tg.clone(), token_inputs(6, seq, vocab, 96)),
    ];
    for (g, inputs) in fixtures {
        let stats = calibrate(&g, &inputs);
        let q16 = Arc::new(quantize(&g, &stats, QuantSpec::int16_per_layer()));
        let q8 = Arc::new(quantize(&g, &stats, QuantSpec::int8_per_layer()));
        let aq = Arc::new(quantize_affine(&g, &stats));
        let sessions = [
            SessionBuilder::float32(g.clone()).build(),
            SessionBuilder::fixed_qmn(q16).build(),
            SessionBuilder::fixed_qmn(q8).build(),
            SessionBuilder::affine_i8(aq).max_batch(4).build(),
        ];
        for sess in &sessions {
            let alloc = &sess.plan().alloc;
            microai::allocator::check_no_conflict(&g, alloc)
                .unwrap_or_else(|e| panic!("{}: shipped plan refused: {e}", sess.meta().backend));
            assert!(
                alloc.arena_elems <= alloc.pooled_elems,
                "{}: planned arena {} exceeds pooled baseline {}",
                sess.meta().backend,
                alloc.arena_elems,
                alloc.pooled_elems
            );
        }
    }
}

/// Backend whose `prepare` ships a deliberately overlapping offset plan:
/// a consumer is parked on its still-live producer's device offset with
/// no in-place sanction. `try_build` must refuse it.
struct OverlappingPlanBackend {
    graph: Arc<Graph>,
}

impl microai::nn::InferenceBackend for OverlappingPlanBackend {
    fn label(&self) -> String {
        "crafted-overlap".into()
    }

    fn dtype(&self) -> microai::mcu::DType {
        microai::mcu::DType::F32
    }

    fn graph(&self) -> &Graph {
        &self.graph
    }

    fn weight_bytes(&self) -> usize {
        0
    }

    fn prepare(&self) -> Result<microai::nn::Plan, microai::analysis::VerifyError> {
        let mut plan = microai::nn::Plan::for_graph(&self.graph, 4);
        let victim = self
            .graph
            .nodes
            .iter()
            .find(|n| {
                !matches!(n.kind, LayerKind::Input)
                    && plan.alloc.inplace_with[n.id].is_none()
                    && n.inputs.iter().any(|&i| plan.alloc.offset_of[i] != usize::MAX)
            })
            .expect("fixture has an out-of-place node with a planned input");
        let producer =
            *victim.inputs.iter().find(|&&i| plan.alloc.offset_of[i] != usize::MAX).unwrap();
        plan.alloc.offset_of[victim.id] = plan.alloc.offset_of[producer];
        Ok(plan)
    }

    fn new_arena(&self, _: &microai::nn::Plan, _: usize, _: usize) -> microai::nn::Arena {
        unreachable!("the overlapping plan must be refused before arena construction")
    }

    fn run<'a>(
        &self,
        _: &microai::nn::Plan,
        _: &'a mut microai::nn::Arena,
        _: &[f32],
    ) -> &'a [f32] {
        unreachable!("the overlapping plan must be refused before any run")
    }
}

#[test]
fn try_build_refuses_a_crafted_overlapping_plan() {
    let g = Arc::new(fixture_graph(1, &[32, 3], 4, 8, 97));
    let backend = OverlappingPlanBackend { graph: g.clone() };

    // The checker alone rejects the corrupted allocation...
    let bad = backend.prepare().unwrap();
    let refusal = microai::allocator::check_no_conflict(&g, &bad.alloc)
        .expect_err("overlapping offsets must not verify");
    assert!(!refusal.is_empty());

    // ...and the builder refuses to construct a session around it.
    let err = SessionBuilder::from_backend(Arc::new(backend))
        .try_build()
        .err()
        .expect("try_build must refuse the overlapping plan");
    let msg = format!("{err}");
    assert!(msg.contains("memory checker"), "unexpected refusal: {msg}");
}
