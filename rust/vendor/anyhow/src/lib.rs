//! Minimal, API-compatible subset of the `anyhow` crate, vendored so the
//! workspace builds without network access. Implements exactly what the
//! `microai` crate uses:
//!
//! - [`Error`]: a message + context chain (no backtraces, no downcasting)
//! - [`Result<T>`] with the `Error` default
//! - [`Context`] on `Result` and `Option` (`context` / `with_context`)
//! - `anyhow!`, `bail!`, `ensure!` macros
//! - `{e}` prints the outermost message, `{e:#}` the full context chain
//!
//! Swap for the real crate by pointing the `anyhow` dependency at the
//! registry; no source changes are required.

use std::fmt::{self, Debug, Display};

/// An error with an optional chain of causes (outermost context first).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Construct from anything printable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &Error> {
        let mut next = Some(self);
        std::iter::from_fn(move || {
            let cur = next?;
            next = cur.source.as_deref();
            Some(cur)
        })
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{e:#}`: whole chain, outermost first, ": "-joined.
            let mut first = true;
            for e in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{}", e.msg)?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let causes: Vec<&Error> = self.chain().skip(1).collect();
        if !causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in causes {
                write!(f, "\n    {}", c.msg)?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Preserve std sources as chain entries.
        let mut msgs = Vec::new();
        msgs.push(e.to_string());
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let mut err: Option<Error> = None;
        for m in msgs.into_iter().rev() {
            err = Some(Error { msg: m, source: err.map(Box::new) });
        }
        err.unwrap()
    }
}

/// `Result` with `anyhow::Error` as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension (subset of `anyhow::Context`).
pub trait Context<T> {
    fn context<C: Display>(self, c: C) -> Result<T>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or any
/// `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::string::ToString::to_string(&$err))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Assert-or-bail.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = io_err().into();
        let e = e.context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let e = x.context("missing key").unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn macros_build_errors() {
        let v = 3;
        let e = anyhow!("bad value {v}");
        assert_eq!(format!("{e}"), "bad value 3");
        let e = anyhow!("bad {}: {}", "slot", 7);
        assert_eq!(format!("{e}"), "bad slot: 7");
        let s = String::from("stringy");
        let e = anyhow!(s);
        assert_eq!(format!("{e}"), "stringy");

        fn guarded(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert!(guarded(5).is_ok());
        assert!(guarded(-1).is_err());
        assert!(guarded(101).is_err());
    }
}
