//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The container this workspace builds in has no xla_extension runtime, so
//! this crate provides:
//!
//! - [`Literal`]: a REAL host-side implementation (shape + typed buffer,
//!   `vec1`/`scalar`/`reshape`/`to_vec`/`get_first_element`), enough for
//!   all marshalling in `microai::runtime::exec`;
//! - [`PjRtClient`] and friends whose constructors return a descriptive
//!   [`Error`], so `Runtime::open` fails gracefully and every
//!   PJRT-dependent test/example takes its existing skip path (the same
//!   behaviour as running without `make artifacts`).
//!
//! Swap in the real bindings by pointing the `xla` dependency at an
//! xla-rs checkout; the API subset here matches it.

use std::fmt::{self, Debug, Display};

#[derive(Clone)]
pub struct Error(pub String);

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla::Error({})", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: xla/PJRT runtime unavailable (offline stub build — link the real xla-rs crate to execute HLO artifacts)"
    ))
}

/// Element buffer of a [`Literal`].
#[derive(Clone, Debug)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl Data {
    fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::U32(v) => v.len(),
        }
    }
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Sized + Copy {
    fn into_data(v: Vec<Self>) -> Data;
    fn from_data(d: &Data) -> Option<&[Self]>;
}

impl NativeType for f32 {
    fn into_data(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn from_data(d: &Data) -> Option<&[Self]> {
        match d {
            Data::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn into_data(v: Vec<Self>) -> Data {
        Data::I32(v)
    }
    fn from_data(d: &Data) -> Option<&[Self]> {
        match d {
            Data::I32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for u32 {
    fn into_data(v: Vec<Self>) -> Data {
        Data::U32(v)
    }
    fn from_data(d: &Data) -> Option<&[Self]> {
        match d {
            Data::U32(v) => Some(v),
            _ => None,
        }
    }
}

/// A host tensor: dims + typed element buffer.
#[derive(Clone, Debug)]
pub struct Literal {
    dims: Vec<i64>,
    data: Data,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: T::into_data(data.to_vec()) }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: vec![], data: T::into_data(vec![v]) }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    /// Reshape without changing the buffer; element counts must agree.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count {} != {n}",
                self.dims,
                self.data.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    /// Flatten to a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        T::from_data(&self.data)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("to_vec: literal element type mismatch".into()))
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T, Error> {
        T::from_data(&self.data)
            .and_then(|s| s.first().copied())
            .ok_or_else(|| Error("get_first_element: empty or type mismatch".into()))
    }

    /// Split a tuple literal into its elements. Stub literals are never
    /// tuples (they only come from stub execution, which cannot happen).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>, Error> {
        Err(unavailable("decompose_tuple"))
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto {}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, Error> {
        let _ = path;
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalar_first_element() {
        let s = Literal::scalar(0.25f32);
        assert_eq!(s.dims().len(), 0);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 0.25);
    }

    #[test]
    fn client_is_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e}").contains("unavailable"));
    }
}
