//! big/LITTLE cascade serving demo (paper §8 future work): a small f=8
//! model answers confident requests, escalating to a f=32 model otherwise.
//! Sweeps the confidence threshold and prints the latency/energy/accuracy
//! trade-off the technique buys on the simulated SparkFun Edge.
//!
//! Run: `make artifacts && cargo run --release --example biglittle_serving`

use microai::coordinator::trainer::{LrSchedule, Trainer};
use microai::coordinator::{deployer, serving};
use microai::datasets;
use microai::mcu::board::SPARKFUN_EDGE;
use microai::nn::SessionBuilder;
use microai::quant::QuantSpec;
use microai::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let steps = 250usize;
    let n_requests = 300usize;
    let rt = Runtime::open_default()?;
    let data = datasets::load("har", 42).unwrap();

    println!("training little (f=8) and big (f=32) int8 models ({steps} steps each)...");
    let mut qgraphs = Vec::new();
    for f in [8usize, 32] {
        let tag = format!("har_f{f}");
        let spec = rt.spec(&tag)?.clone();
        let mut trainer = Trainer::new(&rt, 42 + f as u64);
        let mut state = trainer.init(&tag)?;
        let sched = LrSchedule {
            initial: 0.05,
            factor: 0.13,
            milestones: vec![steps * 5 / 8, steps * 7 / 8],
            warmup: steps / 20,
        };
        trainer.train(&mut state, &data, "train", steps, &sched, 0)?;
        let g = deployer::build_deployed_graph(&spec, trainer.params_to_host(&state)?);
        let (qg, acc) = deployer::ptq_accuracy(&g, &data, QuantSpec::int8_per_layer(), 64);
        println!("  f={f}: int8 accuracy {acc:.4}");
        qgraphs.push(qg);
    }
    let big = qgraphs.pop().unwrap();
    let little = qgraphs.pop().unwrap();

    // Sessions carry the deployment price (mcu::cost via metadata); the
    // cascade workers fork their own sessions from the same weights.
    let little_sess = SessionBuilder::fixed_qmn(little.clone()).board(&SPARKFUN_EDGE).build();
    let big_sess = SessionBuilder::fixed_qmn(big.clone()).board(&SPARKFUN_EDGE).build();
    println!(
        "\npredicted device latency: little {:.1} ms, big {:.1} ms (session metadata, {})",
        little_sess.meta().device_latency_ms.unwrap_or(0.0),
        big_sess.meta().device_latency_ms.unwrap_or(0.0),
        SPARKFUN_EDGE.name,
    );

    let (reqs, labels) = serving::request_stream(&data, n_requests, 7);
    // Open-loop Poisson arrivals at ~the little model's per-worker
    // service rate: low-threshold arms stay stable, while high-escalation
    // arms saturate and their total-latency/queue columns blow up — which
    // is exactly the serving argument for the cascade.
    let little_ms = little_sess.meta().device_latency_ms.unwrap_or(0.0);
    let rate = if little_ms > 0.0 { 1e3 / little_ms } else { 0.0 };
    println!(
        "\n{:>10} {:>12} {:>9} {:>9} {:>9} {:>9} {:>12} {:>10}",
        "threshold", "escalation", "p50(ms)", "p99(ms)", "queue50", "depth99", "energy(µWh)", "accuracy"
    );
    for &threshold in &[0.0f32, 0.5, 0.7, 0.8, 0.9, 0.95, 1.01] {
        let cfg = serving::CascadeConfig {
            threshold,
            workers: 4,
            board: &SPARKFUN_EDGE,
            arrival_rate_hz: rate,
            ..serving::CascadeConfig::default()
        };
        let stats = serving::run_cascade(
            little.clone(),
            big.clone(),
            &cfg,
            reqs.clone(),
            Some(&labels),
        );
        let lat = stats.latency.as_ref().expect("board-priced cascade");
        println!(
            "{:>10.2} {:>11.1}% {:>9.1} {:>9.1} {:>9.1} {:>9.0} {:>12.2} {:>10.4}",
            threshold,
            stats.escalation_rate * 100.0,
            lat.p50,
            lat.p99,
            stats.queue_latency.p50,
            stats.queue_depth.p99,
            stats.total_energy_uwh.unwrap(),
            stats.accuracy.unwrap()
        );
    }
    println!(
        "\n(paper [58]'s claim shape: most requests stay on the little model, \
         keeping p50 near the little latency while accuracy approaches big-only; \
         total latency = queue_ms + device_ms under Poisson arrivals at {rate:.0}/s)"
    );
    Ok(())
}
