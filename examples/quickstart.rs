//! Quickstart: the three layers in one page.
//!
//!   1. Load and run the L1 Pallas kernel artifact (fixed-point matmul)
//!      through PJRT from Rust — the AOT bridge.
//!   2. Build a ResNetv1-6, quantize it to int8 with the Qm.n PTQ
//!      quantizer, and run the integer inference engine.
//!   3. Price the deployment on both paper boards.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use microai::engines::microai;
use microai::graph::ir::LayerKind;
use microai::graph::{deploy_pipeline, resnet_v1_6_shapes};
use microai::mcu::board::{NUCLEO_L452RE_P, SPARKFUN_EDGE};
use microai::mcu::DType;
use microai::nn::float_exec::ActStats;
use microai::nn::SessionBuilder;
use microai::quant::{quantize, QuantSpec};
use microai::runtime::exec::{lit_f32, to_f32};
use microai::runtime::Runtime;
use microai::util::prng::Pcg32;

fn main() -> anyhow::Result<()> {
    // ---- 1. the AOT bridge: Pallas kernel via PJRT ----
    let rt = Runtime::open_default()?;
    let exe = rt.compile("kernel_fixed_matmul.hlo.txt")?;
    let (m, k, n) = (32usize, 24usize, 16usize);
    let xq: Vec<f32> = (0..m * k).map(|i| ((i % 13) as f32) - 6.0).collect();
    let wq: Vec<f32> = (0..k * n).map(|i| ((i % 7) as f32) - 3.0).collect();
    let bq = vec![0.0f32; n];
    let out = exe.run(&[
        lit_f32(&xq, &[m, k])?,
        lit_f32(&wq, &[k, n])?,
        lit_f32(&bq, &[n])?,
        xla::Literal::scalar(0.25f32), // 2^-2 rescale
    ])?;
    let y = to_f32(&out[0])?;
    println!("L1 Pallas fixed_matmul via PJRT: out[0..4] = {:?}", &y[..4]);

    // ---- 2. quantize + integer inference in Rust ----
    let mut g = resnet_v1_6_shapes("quickstart", 1, &[128, 9], 6, 16);
    let mut rng = Pcg32::seeded(7);
    for node in g.nodes.iter_mut() {
        if let LayerKind::Conv { w, b, .. } | LayerKind::Dense { w, b } = &mut node.kind {
            for v in w.data.iter_mut() {
                *v = rng.normal() * 0.3;
            }
            for v in b.data.iter_mut() {
                *v = 0.01;
            }
        }
    }
    let g = deploy_pipeline(&g);
    println!("\nResNetv1-6 (paper Fig 4), {} parameters", g.param_count());

    // Compile once: a float session (doubling as the calibration pass)
    // and an int8 session; run many without per-request allocation.
    let mut float_sess = SessionBuilder::float32(g.clone())
        .board(&SPARKFUN_EDGE)
        .build();
    let mut stats = ActStats::new(g.nodes.len());
    let calib: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..128 * 9).map(|_| rng.normal()).collect())
        .collect();
    for x in &calib {
        float_sess.calibrate(x, &mut stats);
    }
    let qg = quantize(&g, &stats, QuantSpec::int8_per_layer());
    let mut int8_sess = SessionBuilder::fixed_qmn(qg)
        .board(&SPARKFUN_EDGE)
        .build();

    let x: Vec<f32> = (0..128 * 9).map(|_| rng.normal()).collect();
    let fl = float_sess.run(&x).to_vec();
    let il = int8_sess.run(&x).to_vec();
    println!("float  logits: {fl:?}");
    println!("int8   logits: {il:?}");
    for s in [&float_sess, &int8_sess] {
        let m = s.meta();
        println!(
            "session {:<15} weights {:>7} B  device RAM {:>6} B  host arena {:>6} B \
             ({} pools)  predicted {:>7.1} ms / {:>6.3} µWh on {}",
            m.backend,
            m.weight_bytes,
            m.device_ram_bytes,
            m.arena_bytes,
            m.n_pools,
            m.device_latency_ms.unwrap_or(0.0),
            m.device_energy_uwh.unwrap_or(0.0),
            m.board.map(|b| b.name).unwrap_or("-"),
        );
    }

    // ---- 3. deployment cost on the paper's boards ----
    let e = microai();
    for board in [&NUCLEO_L452RE_P, &SPARKFUN_EDGE] {
        for dt in [DType::F32, DType::I16, DType::I8] {
            let t = e.latency_s(&g, board, dt).unwrap() * 1e3;
            let en = e.energy_uwh(&g, board, dt).unwrap();
            println!("{:<14} {:<8} {t:>7.1} ms  {en:>6.3} µWh", board.name, dt.label());
        }
    }
    Ok(())
}
