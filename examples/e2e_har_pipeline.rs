//! END-TO-END driver (the DESIGN.md validation workload): the complete
//! MicroAI pipeline of paper Fig 3 on the synthetic UCI-HAR workload.
//!
//!   train (float32, a few hundred SGD steps through the AOT HLO train
//!   step, loss curve logged) → PTQ int16 / int9 / int8 + TFLite-affine
//!   int8 → QAT int8 fine-tune → accuracy table (paper Figs 5/6 row) →
//!   deployment matrix across engines × boards (Figs 11–13 cells) → C
//!   library generation (KerasCNN2C analogue).
//!
//! Results of a reference run are recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example e2e_har_pipeline`

use microai::coordinator::deployer;
use microai::coordinator::trainer::{LrSchedule, Trainer};
use microai::datasets;
use microai::engines::all_engines;
use microai::mcu::board::{BOARDS, SPARKFUN_EDGE};
use microai::nn::{Batch, SessionBuilder};
use microai::quant::QuantSpec;
use microai::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let t0 = std::time::Instant::now();
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let filters = 16usize;
    let tag = format!("har_f{filters}");

    println!("== MicroAI end-to-end pipeline: synthetic UCI-HAR, f={filters} ==\n");
    let rt = Runtime::open_default()?;
    let spec = rt.spec(&tag)?.clone();
    let data = datasets::load("har", 42).unwrap();
    println!(
        "dataset: {} train / {} test examples, shape {:?}, {} classes",
        data.n_train(),
        data.n_test(),
        data.shape,
        data.classes
    );

    // ---- Phase 1: float32 training from Rust via the HLO train step ----
    println!("\n-- phase 1: float32 training ({steps} SGD steps, batch {}) --", spec.train_batch);
    let mut trainer = Trainer::new(&rt, 42);
    let mut state = trainer.init(&tag)?;
    let sched = LrSchedule {
        initial: 0.05,
        factor: 0.13,
        milestones: vec![steps * 5 / 8, steps * 3 / 4, steps * 7 / 8],
        warmup: steps / 20,
    };
    trainer.train(&mut state, &data, "train", steps, &sched, (steps / 12).max(1))?;
    // Loss curve summary (the "log the loss curve" requirement).
    let curve: Vec<String> = state
        .losses
        .iter()
        .step_by((steps / 16).max(1))
        .map(|l| format!("{l:.3}"))
        .collect();
    println!("loss curve: {}", curve.join(" -> "));

    // ---- Phase 2: QAT int8 fine-tune (paper §4.3) ----
    let qat_steps = (steps / 4).max(20);
    println!("\n-- phase 2: QAT int8 fine-tune ({qat_steps} steps) --");
    let mut qat_state = microai::coordinator::trainer::TrainState {
        tag: state.tag.clone(),
        params: state.params.clone(),
        mom: state.mom.clone(),
        losses: Vec::new(),
    };
    let qat_sched = LrSchedule {
        initial: 0.01,
        factor: 0.1,
        milestones: vec![qat_steps / 2],
        warmup: 5,
    };
    trainer.train(&mut qat_state, &data, "qat8_train", qat_steps, &qat_sched, 0)?;

    // ---- Phase 3: quantization arms + accuracy (Figs 5/6 row) ----
    println!("\n-- phase 3: quantization & accuracy (Rust integer engine) --");
    let graph = deployer::build_deployed_graph(&spec, trainer.params_to_host(&state)?);
    let qat_graph = deployer::build_deployed_graph(&spec, trainer.params_to_host(&qat_state)?);

    let acc_float = deployer::float_accuracy(&graph, &data);
    let (q16, acc16) = deployer::ptq_accuracy(&graph, &data, QuantSpec::int16_per_layer(), 64);
    let (q9, acc9) = deployer::ptq_accuracy(&graph, &data, QuantSpec::int9_per_layer(), 64);
    let (q8p, acc8p) = deployer::ptq_accuracy(&graph, &data, QuantSpec::int8_per_layer(), 64);
    let (_q8, acc8qat) =
        deployer::ptq_accuracy(&qat_graph, &data, QuantSpec::int8_per_layer(), 64);
    let acc_affine = deployer::affine_accuracy(&graph, &data, 64);

    println!("{:<26} {:>9} {:>12}", "variant", "accuracy", "weights(B)");
    println!("{:<26} {:>9.4} {:>12}", "float32", acc_float, graph.param_count() * 4);
    println!("{:<26} {:>9.4} {:>12}", "int16 PTQ (per-layer)", acc16, q16.weight_bytes());
    println!("{:<26} {:>9.4} {:>12}", "int9 PTQ (App. B)", acc9, q9.weight_bytes());
    println!("{:<26} {:>9.4} {:>12}", "int8 PTQ", acc8p, q8p.weight_bytes());
    println!("{:<26} {:>9.4} {:>12}", "int8 QAT", acc8qat, q8p.weight_bytes());
    println!("{:<26} {:>9.4} {:>12}", "int8 affine (TFLite-PTQ)", acc_affine, graph.param_count());

    // ---- Phase 3b: one model, three engines, one Session API ----
    println!("\n-- phase 3b: cross-backend sessions (unified inference API) --");
    let stats = deployer::calibrate(&graph, &data, 64);
    let aq = microai::quant::quantize_affine(&graph, &stats);
    let mut sessions = vec![
        SessionBuilder::float32(graph.clone()).board(&SPARKFUN_EDGE).build(),
        SessionBuilder::fixed_qmn(q16.clone()).board(&SPARKFUN_EDGE).build(),
        SessionBuilder::fixed_qmn(q8p.clone()).board(&SPARKFUN_EDGE).build(),
        SessionBuilder::affine_i8(aq).board(&SPARKFUN_EDGE).build(),
    ];
    let probe = data.test_example(0);
    let mut preds = Vec::new();
    for sess in sessions.iter_mut() {
        preds.clear();
        sess.infer(&Batch::single(probe), &mut preds);
        let pred = preds[0];
        let m = sess.meta();
        println!(
            "  {:<16} -> class {} (conf {:.2})  {:>7} B weights  {:>6} B RAM  \
             {:>7.1} ms  {:>6.3} µWh",
            m.backend,
            pred.class,
            pred.confidence,
            m.weight_bytes,
            m.device_ram_bytes,
            m.device_latency_ms.unwrap_or(0.0),
            m.device_energy_uwh.unwrap_or(0.0),
        );
    }

    // ---- Phase 4: deployment matrix (Figs 11-13 cells) ----
    println!("\n-- phase 4: deployment matrix (engines x boards) --");
    let rows = deployer::deployment_matrix(&graph, filters, &all_engines(), &BOARDS);
    print!("{}", deployer::render_matrix(&rows));

    // ---- Phase 5: C library generation ----
    println!("\n-- phase 5: C code generation (KerasCNN2C analogue) --");
    let stats = deployer::calibrate(&graph, &data, 64);
    let qg = microai::quant::quantize(&graph, &stats, QuantSpec::int8_per_layer());
    let lib = microai::codegen::generate(&qg);
    let out = std::path::Path::new("results/e2e_generated_c");
    microai::codegen::write_to(&lib, out)?;
    println!(
        "wrote {}/number.h, model.h, model.c ({} B of C)",
        out.display(),
        lib.model_c.len()
    );

    println!("\n== pipeline complete in {:.1}s ==", t0.elapsed().as_secs_f64());
    println!(
        "paper-shape checks: int16≈float ({acc16:.3} vs {acc_float:.3}); \
         int8-QAT ≥ int8-PTQ ({acc8qat:.3} vs {acc8p:.3})"
    );
    Ok(())
}
