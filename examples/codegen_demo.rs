//! C code generation demo (KerasCNN2C analogue, §5.6–5.8): quantize a
//! trained model, emit the portable C library, compile it with the host C
//! compiler, run one inference, and verify it agrees with the Rust
//! integer engine.
//!
//! Run: `make artifacts && cargo run --release --example codegen_demo`

use std::io::Write as _;
use std::process::Command;

use microai::coordinator::deployer;
use microai::coordinator::trainer::{LrSchedule, Trainer};
use microai::datasets;
use microai::quant::QuantSpec;
use microai::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default()?;
    let tag = "har_f16";
    let spec = rt.spec(tag)?.clone();
    let data = datasets::load("har", 42).unwrap();

    println!("training {tag} (150 steps)...");
    let mut trainer = Trainer::new(&rt, 42);
    let mut state = trainer.init(tag)?;
    let sched = LrSchedule { initial: 0.05, factor: 0.13, milestones: vec![100], warmup: 8 };
    trainer.train(&mut state, &data, "train", 150, &sched, 0)?;
    let graph = deployer::build_deployed_graph(&spec, trainer.params_to_host(&state)?);
    let stats = deployer::calibrate(&graph, &data, 64);
    let qg = microai::quant::quantize(&graph, &stats, QuantSpec::int8_per_layer());

    let lib = microai::codegen::generate(&qg);
    let dir = std::path::Path::new("results/codegen_demo");
    microai::codegen::write_to(&lib, dir)?;
    println!("generated C library in {}:", dir.display());
    println!("--- model.h ---\n{}", lib.model_h);

    // Compile with the host compiler (stands in for arm-none-eabi-gcc).
    let main_c = r#"
#include <stdio.h>
#include "model.h"
int main(void) {
    static number_t input[MODEL_INPUT_SAMPLES][MODEL_INPUT_CHANNELS];
    static number_t output[MODEL_OUTPUT_UNITS];
    for (int s = 0; s < MODEL_INPUT_SAMPLES; s++)
        for (int c = 0; c < MODEL_INPUT_CHANNELS; c++) {
            long v; if (scanf("%ld", &v) != 1) return 1;
            input[s][c] = (number_t)v;
        }
    cnn(input, output);
    for (int i = 0; i < MODEL_OUTPUT_UNITS; i++) printf("%d\n", (int)output[i]);
    return 0;
}
"#;
    std::fs::write(dir.join("main.c"), main_c)?;
    let bin = dir.join("demo");
    let status = Command::new("cc")
        .args(["-Ofast", "-o"])
        .arg(&bin)
        .arg(dir.join("main.c"))
        .arg(dir.join("model.c"))
        .arg("-I")
        .arg(dir)
        .status();
    let Ok(status) = status else {
        println!("(no host cc — skipping compile check)");
        return Ok(());
    };
    anyhow::ensure!(status.success(), "cc failed");
    println!("compiled with cc -Ofast (paper uses GCC -Ofast, §5.7)");

    // Run one test example through both the C binary and the Rust engine.
    let x = data.test_example(0);
    let in_fmt = microai::fixedpoint::QFormat::new(8, qg.act_n[0]);
    let payload: Vec<i32> = x.iter().map(|&v| in_fmt.quantize(v)).collect();
    let mut child = Command::new(&bin)
        .stdin(std::process::Stdio::piped())
        .stdout(std::process::Stdio::piped())
        .spawn()?;
    child
        .stdin
        .as_mut()
        .unwrap()
        .write_all(payload.iter().map(|p| p.to_string()).collect::<Vec<_>>().join("\n").as_bytes())?;
    let out = child.wait_with_output()?;
    let c_out: Vec<i32> = String::from_utf8(out.stdout)?
        .lines()
        .map(|l| l.trim().parse().unwrap())
        .collect();

    let out_fmt = microai::fixedpoint::QFormat::new(8, qg.act_n[qg.graph.output_id()]);
    let mut sess = microai::nn::SessionBuilder::fixed_qmn(qg).build();
    let rust_logits = sess.run(x).to_vec();
    let rust_out: Vec<i32> = rust_logits.iter().map(|&v| out_fmt.quantize(v)).collect();

    println!("C payloads:    {c_out:?}");
    println!("Rust payloads: {rust_out:?}");
    anyhow::ensure!(c_out == rust_out, "C and Rust disagree!");
    println!(
        "bit-exact ✓  (true label = {}, prediction = {})",
        data.test_y[0],
        microai::nn::argmax(&rust_logits)
    );
    Ok(())
}
