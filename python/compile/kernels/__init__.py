"""L1 Pallas kernels + pure-jnp oracles for the MicroAI reproduction."""
from . import fake_quant, fixed_matmul, quant_math, ref  # noqa: F401
