"""L1 Pallas kernel: fixed-point matmul with shift/saturate epilogue.

This is the paper's inference hot-spot (§5.8, Table A6): int8 operands,
wide accumulator, arithmetic-shift-right rescale, saturation, optional fused
ReLU — exactly the semantics of the generated C inner loop, and of the Rust
integer engine (`rust/src/nn/int_ops.rs`). Convolutions reach this kernel
through im2col (ref.im2col_1d/2d), mirroring how the MCU code streams
patches through a MACC loop.

Hardware adaptation: the Cortex-M4 loop is one MACC/cycle (SMLABB); on TPU
the same contraction is an MXU matmul over (bm, bk)×(bk, bn) VMEM tiles.
Operands are integer-valued float32 (exact while |acc| < 2^24, guaranteed
for int8 operands with K ≤ 2^9), because the CPU interpret path and the
MXU's bf16/int8 paths both reduce into ≥24-bit accumulators.

The rescale multiplier (2^-shift) is a traced scalar operand: the Qm.n
shift differs per layer and, under QAT, per batch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant_math import qmn_limits

# MXU-friendly tiles. K is kept whole (layer contractions here are ≤ a few
# hundred), so each grid step is one (bm, K) × (K, bn) VMEM-resident matmul.
_BM = 128
_BN = 128


def _fixed_matmul_kernel(x_ref, w_ref, b_ref, mult_ref, o_ref, *, lo, hi, relu):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...]
    out = jnp.floor(acc * mult_ref[0, 0])
    out = jnp.clip(out, lo, hi)
    if relu:
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out


def _pad_to(a, rows, cols):
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


@functools.partial(jax.jit, static_argnames=("width", "relu"))
def fixed_matmul(
    xq: jax.Array,
    wq: jax.Array,
    bq: jax.Array,
    out_mult: jax.Array,
    width: int = 8,
    relu: bool = False,
) -> jax.Array:
    """Quantized (M,K)×(K,N) matmul with bias, rescale, saturate, [ReLU].

    xq, wq: integer-valued float32 fixed-point payloads.
    bq: (N,) bias already in the accumulator scale (n_x + n_w bits).
    out_mult: scalar 2^-shift taking the accumulator to the output scale.
    """
    m, k = xq.shape
    k2, n = wq.shape
    assert k == k2, (k, k2)
    lo, hi = qmn_limits(width)
    mp = -(-m // _BM) * _BM
    np_ = -(-n // _BN) * _BN
    xp = _pad_to(xq, mp, k)
    wp = _pad_to(wq, k, np_)
    bp = jnp.pad(bq, (0, np_ - n)).reshape(1, np_)
    grid = (mp // _BM, np_ // _BN)
    out = pl.pallas_call(
        functools.partial(
            _fixed_matmul_kernel, lo=float(lo), hi=float(hi), relu=relu
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((_BM, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, _BN), lambda i, j: (0, j)),
            pl.BlockSpec((1, _BN), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((_BM, _BN), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp, out_mult.reshape(1, 1).astype(jnp.float32))
    return out[:m, :n]
