"""Shared fixed-point quantization math (paper §4.1.4, Eqs 1-4).

This is the single source of truth for the Qm.n scale-factor rule used by
the JAX model (L2), the Pallas kernels (L1) and — re-implemented in Rust —
the MicroAI quantizer (L3, `rust/src/quant/`). The Rust unit tests pin the
same vectors as `python/tests/test_quant_math.py` so the three layers agree.

Conventions (match the paper exactly):
  m = 1 + floor(log2(max_i |x_i|))       # bits for the unsigned integer part
  n = w - m - 1                          # bits for the fractional part
  x_fixed = trunc(x * 2^n)               # truncation toward zero
  s = 2^-n                               # scale factor (power of two)

A value set with max|x| == 0 gets the maximum fractional precision
(n = w - 1), mirroring the Rust implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "frac_bits",
    "quantize_to_int",
    "fake_quant",
    "qmn_limits",
]


def qmn_limits(width: int) -> tuple[int, int]:
    """Inclusive integer limits of a signed `width`-bit fixed-point value."""
    lo = -(1 << (width - 1))
    hi = (1 << (width - 1)) - 1
    return lo, hi


def frac_bits(x: jax.Array, width: int) -> jax.Array:
    """Number of fractional bits `n` for the vector `x` (Eqs 1-2).

    Returns a float32 scalar (kept float so that `exp2` stays cheap inside
    a jitted graph); its value is always an exact small integer.
    """
    maxabs = jnp.max(jnp.abs(x))
    # Eq 1: m = 1 + floor(log2(max|x|)); an all-zero vector takes m = 0
    # (n = w - 1, maximum fractional precision) by convention — the Rust
    # quantizer (rust/src/quant) pins the same rule.
    m = 1.0 + jnp.floor(jnp.log2(jnp.maximum(maxabs, 1e-38)))
    m = jnp.where(maxabs > 0, m, 0.0)
    # Eq 2: n = w - m - 1.
    n = width - m - 1.0
    return n.astype(jnp.float32)


def quantize_to_int(x: jax.Array, n: jax.Array, width: int) -> jax.Array:
    """Eq 3 with saturation: integer-valued float tensor trunc(x * 2^n).

    The result is kept in float32 (holding exact small integers) so that it
    can flow through XLA/Pallas on any backend; the Rust engine stores the
    same values as i8/i16.
    """
    lo, hi = qmn_limits(width)
    scaled = jnp.trunc(x * jnp.exp2(n))
    return jnp.clip(scaled, float(lo), float(hi))


def fake_quant(x: jax.Array, width: int) -> jax.Array:
    """Quantize-dequantize with a straight-through estimator (paper §4.3).

    Forward: clip(trunc(x * 2^n), lo, hi) * 2^-n  with n from Eqs 1-2.
    Backward: identity (STE), so QAT gradients flow through.
    """
    n = frac_bits(x, width)
    q = quantize_to_int(x, n, width) * jnp.exp2(-n)
    return x + jax.lax.stop_gradient(q - x)
