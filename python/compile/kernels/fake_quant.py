"""L1 Pallas kernel: fake quantization (quantize-dequantize) of a tensor.

This is the QAT hot-spot of the paper (§4.3, Fig 2): every conv/dense input,
weight and output goes through quantize-dequantize during the forward pass.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): on the Cortex-M4 the
paper implements this as a scalar trunc/saturate loop; on TPU the same
element-wise epilogue is a VPU op over a VMEM-resident tile. The kernel is
tiled along the leading dimension so each block fits VMEM; the scale is a
broadcast scalar operand (SMEM-like (1,1) block).

interpret=True is mandatory here: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO that the Rust runtime can
load and run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant_math import qmn_limits

# VPU-friendly tile: 8×128 lanes per step; the row tile is kept modest so
# that worst-case (rows, cols) blocks stay far below the ~16 MiB VMEM budget.
_ROW_TILE = 256


def _fake_quant_kernel(x_ref, scale_ref, o_ref, *, lo: float, hi: float):
    scale = scale_ref[0, 0]
    q = jnp.clip(jnp.trunc(x_ref[...] * scale), lo, hi)
    o_ref[...] = q / scale


@functools.partial(jax.jit, static_argnames=("width",))
def fake_quant(x: jax.Array, scale: jax.Array, width: int = 8) -> jax.Array:
    """Quantize-dequantize `x` (any shape) with scale = 2^n, `width` bits.

    The scale is a traced scalar (recomputed per batch during QAT, frozen at
    inference — paper §4.3), so it is passed as an operand rather than baked
    into the kernel.
    """
    lo, hi = qmn_limits(width)
    orig_shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    # Pad to a whole number of row tiles of 128 lanes.
    cols = 128
    rows = -(-n // cols)
    rows_pad = -(-rows // _ROW_TILE) * _ROW_TILE
    buf = jnp.zeros((rows_pad * cols,), x.dtype).at[:n].set(flat)
    buf = buf.reshape(rows_pad, cols)
    grid = (rows_pad // _ROW_TILE,)
    out = pl.pallas_call(
        functools.partial(_fake_quant_kernel, lo=float(lo), hi=float(hi)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((_ROW_TILE, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((_ROW_TILE, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_pad, cols), x.dtype),
        interpret=True,
    )(buf, scale.reshape(1, 1).astype(x.dtype))
    return out.reshape(-1)[:n].reshape(orig_shape)
