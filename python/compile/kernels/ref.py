"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every Pallas kernel in this package has a reference implementation here,
written with plain jnp ops and no tiling, used by pytest/hypothesis to
check numerics. The oracles also serve as the L2 building blocks when the
Pallas path is disabled (e.g. inside the training step, where interpret-mode
Pallas would slow lowering down without changing the math).
"""

from __future__ import annotations

import jax.numpy as jnp

from .quant_math import qmn_limits


def fake_quant_with_scale_ref(x, scale, width: int):
    """Reference for kernels.fake_quant: clip(trunc(x*scale), lo, hi)/scale."""
    lo, hi = qmn_limits(width)
    q = jnp.clip(jnp.trunc(x * scale), float(lo), float(hi))
    return q / scale


def fixed_matmul_ref(xq, wq, out_mult, width: int):
    """Reference for kernels.fixed_matmul.

    xq: (M, K) integer-valued float32 (fixed-point payload)
    wq: (K, N) integer-valued float32
    out_mult: scalar 2^-shift rescale multiplier (power of two)
    Semantics of the generated C (paper §5.8 / Table A6): widen, MACC,
    arithmetic-shift-right (floor), saturate to `width` bits.
    """
    lo, hi = qmn_limits(width)
    acc = xq @ wq  # exact in f32 while |acc| < 2^24 (int8 operands)
    out = jnp.floor(acc * out_mult)  # ASR == floor division for 2^k scales
    return jnp.clip(out, float(lo), float(hi))


def fixed_matmul_bias_ref(xq, wq, bq, out_mult, width: int, relu: bool):
    """fixed_matmul with accumulator-scale bias add and optional fused ReLU.

    `bq` must already be expressed in the accumulator's scale
    (n_x + n_w fractional bits), exactly like the Rust engine and the
    generated C (§5.8: operands of an addition must share the format).
    """
    lo, hi = qmn_limits(width)
    acc = xq @ wq + bq[None, :]
    out = jnp.floor(acc * out_mult)
    out = jnp.clip(out, float(lo), float(hi))
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def im2col_1d(x, kernel: int, stride: int, pad_lo: int, pad_hi: int):
    """Unroll a (B, S, C) input into (B, S_out, kernel*C) patches.

    Tap-major, channel-minor ordering — matches w.reshape(k*C, F) for a
    WIO-layout weight tensor (k, C, F).
    """
    b, s, c = x.shape
    xp = jnp.pad(x, ((0, 0), (pad_lo, pad_hi), (0, 0)))
    s_out = (s + pad_lo + pad_hi - kernel) // stride + 1
    taps = [xp[:, i : i + s_out * stride : stride, :] for i in range(kernel)]
    return jnp.concatenate(taps, axis=-1), s_out


def im2col_2d(x, kh: int, kw: int, stride: int, pads):
    """Unroll a (B, H, W, C) input into (B, H_out, W_out, kh*kw*C) patches.

    Row-major over (tap_h, tap_w), channel-minor — matches
    w.reshape(kh*kw*C, F) for an HWIO-layout weight tensor.
    """
    b, h, w, c = x.shape
    (plh, phh), (plw, phw) = pads
    xp = jnp.pad(x, ((0, 0), (plh, phh), (plw, phw), (0, 0)))
    h_out = (h + plh + phh - kh) // stride + 1
    w_out = (w + plw + phw - kw) // stride + 1
    taps = []
    for i in range(kh):
        for j in range(kw):
            taps.append(
                xp[:, i : i + h_out * stride : stride, j : j + w_out * stride : stride, :]
            )
    return jnp.concatenate(taps, axis=-1), h_out, w_out


def same_padding(size: int, kernel: int, stride: int) -> tuple[int, int]:
    """XLA SAME padding amounts (lo, hi) for one spatial dimension."""
    out = -(-size // stride)  # ceil
    total = max((out - 1) * stride + kernel - size, 0)
    return total // 2, total - total // 2
