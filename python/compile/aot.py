"""AOT lowering: JAX (L2) + Pallas (L1) → HLO text artifacts for Rust (L3).

Run once at build time (`make artifacts`). Emits, per (dataset, filters):

  init_{d}_f{f}.hlo.txt       (key u32[2]) -> tuple(params...)
  train_{d}_f{f}.hlo.txt      (params..., mom..., x, y i32, key u32[2],
                               lr f32) -> tuple(params'..., mom'..., loss)
  qat8_train_{d}_f{f}.hlo.txt same signature, int8 fake-quant forward
  fwd_{d}_f{f}.hlo.txt        (params..., x) -> tuple(logits)
  qfwd8_{d}_f{f}.hlo.txt      (params..., x) -> tuple(logits), int8 Pallas
                              integer path (L1 fixed_matmul kernels)

plus kernel demo artifacts and artifacts/manifest.json describing every
signature for `rust/src/runtime/artifact.rs`.

Interchange is HLO TEXT, not a serialized HloModuleProto: jax >= 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import fixed_matmul as fm_kernel

# Accuracy-figure sweep (DESIGN.md §6): reduced vs the paper's {16..80} to
# keep CPU training tractable; the footprint/latency/energy tables use the
# paper's full sweep through the Rust cost model (no artifacts needed).
SWEEPS = {
    "har": [8, 16, 32, 64],
    "smnist": [8, 16, 32, 64],
    "gtsrb": [8, 16, 32],
}
TRAIN_BATCH = {"har": 64, "smnist": 64, "gtsrb": 32}
EVAL_BATCH = 128


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_specs(cfg):
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return [_spec(p.shape) for p in params], [list(p.shape) for p in params]


def lower_model(d: str, f: int, outdir: str, manifest: dict) -> None:
    cfg = M.make_config(d, f)
    pspecs, pshapes = _param_specs(cfg)
    tb = TRAIN_BATCH[d]
    x_train = _spec((tb,) + cfg.input_shape)
    y_train = _spec((tb,), jnp.int32)
    x_eval = _spec((EVAL_BATCH,) + cfg.input_shape)
    key_spec = _spec((2,), jnp.uint32)
    lr_spec = _spec((), jnp.float32)
    tag = f"{d}_f{f}"

    def init_fn(key_data):
        key = jax.random.wrap_key_data(key_data, impl="threefry2x32")
        return tuple(M.init_params(key, cfg))

    def train_fn(*args):
        n = len(pspecs)
        params, mom = list(args[:n]), list(args[n : 2 * n])
        x, y, key, lr = args[2 * n : 2 * n + 4]
        p2, m2, loss = M.train_step(params, mom, x, y, key, lr, cfg)
        return tuple(p2) + tuple(m2) + (loss,)

    def qat_train_fn(*args):
        n = len(pspecs)
        params, mom = list(args[:n]), list(args[n : 2 * n])
        x, y, key, lr = args[2 * n : 2 * n + 4]
        p2, m2, loss = M.train_step(params, mom, x, y, key, lr, cfg, width=8)
        return tuple(p2) + tuple(m2) + (loss,)

    def fwd_fn(*args):
        params, x = list(args[:-1]), args[-1]
        return (M.apply(params, x, cfg),)

    def qfwd8_fn(*args):
        params, x = list(args[:-1]), args[-1]
        return (M.apply(params, x, cfg, width=8, use_pallas=True),)

    train_in = pspecs + pspecs + [x_train, y_train, key_spec, lr_spec]
    arts = {}
    for name, fn, specs in [
        ("init", init_fn, [key_spec]),
        ("train", train_fn, train_in),
        ("qat8_train", qat_train_fn, train_in),
        ("fwd", fwd_fn, pspecs + [x_eval]),
        ("qfwd8", qfwd8_fn, pspecs + [x_eval]),
    ]:
        fname = f"{name}_{tag}.hlo.txt"
        text = to_hlo_text(jax.jit(fn).lower(*specs))
        with open(os.path.join(outdir, fname), "w") as fh:
            fh.write(text)
        arts[name] = fname
        print(f"  wrote {fname} ({len(text)} chars)")

    manifest["models"][tag] = {
        "dataset": d,
        "filters": f,
        "dims": cfg.dims,
        "input_shape": list(cfg.input_shape),
        "classes": cfg.classes,
        "train_batch": tb,
        "eval_batch": EVAL_BATCH,
        "param_names": M.PARAM_NAMES,
        "param_shapes": pshapes,
        "artifacts": arts,
    }


def lower_kernels(outdir: str, manifest: dict) -> None:
    """Standalone L1 kernel artifacts: quickstart demo + Rust parity tests."""
    m, k, n = 32, 24, 16

    def quickstart_fn(xq, wq, bq, mult):
        return (fm_kernel.fixed_matmul(xq, wq, bq, mult, width=8, relu=True),)

    specs = [_spec((m, k)), _spec((k, n)), _spec((n,)), _spec(())]
    text = to_hlo_text(jax.jit(quickstart_fn).lower(*specs))
    with open(os.path.join(outdir, "kernel_fixed_matmul.hlo.txt"), "w") as fh:
        fh.write(text)
    manifest["kernels"]["fixed_matmul"] = {
        "file": "kernel_fixed_matmul.hlo.txt",
        "m": m, "k": k, "n": n, "width": 8, "relu": True,
        "inputs": ["xq f32[m,k]", "wq f32[k,n]", "bq f32[n]", "mult f32[]"],
    }
    print("  wrote kernel_fixed_matmul.hlo.txt")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="dataset filter, e.g. har")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"version": 1, "models": {}, "kernels": {}}
    lower_kernels(args.out, manifest)
    for d, filters in SWEEPS.items():
        if args.only and d != args.only:
            continue
        for f in filters:
            print(f"lowering {d} f={f} ...")
            lower_model(d, f, args.out, manifest)

    with open(os.path.join(args.out, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=1)
    print(f"manifest: {len(manifest['models'])} models")


if __name__ == "__main__":
    main()
