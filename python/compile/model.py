"""L2: the paper's model — ResNetv1-6 (Fig 4) in JAX, float and QAT forward,
plus the SGD(+momentum, +weight-decay, +mixup) training step of §6.

Everything here runs at BUILD TIME only: `aot.py` lowers these functions to
HLO text artifacts that the Rust coordinator loads through PJRT. Python is
never on the request path.

Architecture (reverse-engineered from Fig 4 and the 3958-byte int8 @ f=16
datapoint, DESIGN.md §7):

    Conv(k=3, f, SAME) + ReLU
    MaxPool(2)
    Block1: Conv3-ReLU-Conv3, identity shortcut, Add, ReLU
    MaxPool(2)
    Block2: Conv3(stride 2)-ReLU-Conv3, 1x1-conv(stride 2) shortcut, Add, ReLU
    GlobalAvgPool
    Dense(classes)

The 2D variant (GTSRB) uses 3x3 convs and 2x2 pools. All convs carry a bias
(no BatchNorm — §4.3: "we do not use batch normalization in our
experiments"; BN folding is still implemented in the Rust graph passes for
completeness).

Parameter order is the deployment contract shared with Rust
(`runtime::artifact`): see PARAM_NAMES.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.quant_math import fake_quant, frac_bits, quantize_to_int
from .kernels import fake_quant as fq_kernel
from .kernels import fixed_matmul as fm_kernel
from .kernels.ref import im2col_1d, im2col_2d, same_padding

PARAM_NAMES = [
    "c1w", "c1b",
    "b1c1w", "b1c1b", "b1c2w", "b1c2b",
    "b2c1w", "b2c1b", "b2c2w", "b2c2b",
    "scw", "scb",
    "dw", "db",
]

MOMENTUM = 0.9
WEIGHT_DECAY = 5e-4
MIXUP_ALPHA = 0.2


@dataclass(frozen=True)
class ModelConfig:
    """Static configuration of one ResNetv1-6 instance."""

    dims: int           # 1 or 2 spatial dimensions
    input_shape: tuple  # (S, C) or (H, W, C)
    classes: int
    filters: int
    kernel: int = 3

    @property
    def in_channels(self) -> int:
        return self.input_shape[-1]


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig):
    """He-normal conv weights, Glorot dense, zero biases.

    Returns a list of arrays in PARAM_NAMES order.
    """
    f, c, k = cfg.filters, cfg.in_channels, cfg.kernel
    keys = jax.random.split(key, 7)

    def he(key, shape, fan_in):
        return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)

    # Small positive bias reduces dead-ReLU inits, which otherwise pin the
    # 43-class model at the ln(C) plateau with vanishing gradients.
    bias = lambda n: jnp.full((n,), 0.01, jnp.float32)

    if cfg.dims == 1:
        conv_shape = lambda ci, co: (k, ci, co)
        one_shape = lambda ci, co: (1, ci, co)
        fan = lambda ci: k * ci
    else:
        conv_shape = lambda ci, co: (k, k, ci, co)
        one_shape = lambda ci, co: (1, 1, ci, co)
        fan = lambda ci: k * k * ci

    params = [
        he(keys[0], conv_shape(c, f), fan(c)), bias(f),
        he(keys[1], conv_shape(f, f), fan(f)), bias(f),
        he(keys[2], conv_shape(f, f), fan(f)), bias(f),
        he(keys[3], conv_shape(f, f), fan(f)), bias(f),
        he(keys[4], conv_shape(f, f), fan(f)), bias(f),
        he(keys[5], one_shape(f, f), f), bias(f),
        # Damped classifier init: near-zero logits at start avoid the
        # uniform-softmax collapse basin that mixup + 43 classes can hit.
        jax.random.normal(keys[6], (f, cfg.classes), jnp.float32)
        * (0.1 * jnp.sqrt(1.0 / f)),
        bias(cfg.classes),
    ]
    assert len(params) == len(PARAM_NAMES)
    return params


def param_count(cfg: ModelConfig) -> int:
    key = jax.random.PRNGKey(0)
    return sum(int(p.size) for p in init_params(key, cfg))


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------

def _conv(x, w, b, stride: int, dims: int):
    if dims == 1:
        dn = ("NWC", "WIO", "NWC")
        strides = (stride,)
    else:
        dn = ("NHWC", "HWIO", "NHWC")
        strides = (stride, stride)
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=strides, padding="SAME", dimension_numbers=dn
    )
    return y + b


def _maxpool(x, dims: int, size: int = 2):
    if dims == 1:
        window = (1, size, 1)
        strides = (1, size, 1)
    else:
        window = (1, size, size, 1)
        strides = (1, size, size, 1)
    # SAME: odd spatial dims keep a remainder window (padded with -inf, so
    # the max ignores it) instead of silently dropping the tail samples —
    # mirrored exactly by the Rust/C engines (Graph::pool_geometry).
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, window, strides, "SAME"
    )


def _global_avgpool(x, dims: int):
    axes = (1,) if dims == 1 else (1, 2)
    return jnp.mean(x, axis=axes)


# ---------------------------------------------------------------------------
# Quantization wrappers (QAT forward, paper Fig 2)
# ---------------------------------------------------------------------------

def _maybe_fq(x, width, use_pallas: bool):
    """Fake-quantize with dynamically reassessed scale (paper §4.3)."""
    if width is None:
        return x
    if use_pallas:
        n = frac_bits(x, width)
        return fq_kernel.fake_quant(x, jnp.exp2(n), width)
    return fake_quant(x, width)


def _qconv(x, w, b, stride, dims, width, use_pallas, relu):
    """Conv in QAT mode: quantize inputs/weights/bias, compute, quantize out.

    With use_pallas=True the contraction itself runs through the L1
    fixed_matmul kernel on im2col patches — the same integer dataflow as the
    MCU inner loop (trunc/shift/saturate included).
    """
    if width is None:
        y = _conv(x, w, b, stride, dims)
        return jnp.maximum(y, 0.0) if relu else y

    if not use_pallas:
        xq = fake_quant(x, width)
        wq = fake_quant(w, width)
        bq = fake_quant(b, width)
        y = _conv(xq, wq, bq, stride, dims)
        y = jnp.maximum(y, 0.0) if relu else y
        return fake_quant(y, width)

    # --- Pallas integer path (inference artifacts) ---
    nx = frac_bits(x, width)
    nw = frac_bits(w, width)
    xq = quantize_to_int(x, nx, width)          # int payload in f32
    wq = quantize_to_int(w, nw, width)
    # Bias is expressed directly in the accumulator scale (nx + nw).
    bacc = jnp.trunc(b * jnp.exp2(nx + nw))
    # Output scale: reassessed from the float-path output range.
    yf = _conv(x, w, b, stride, dims)
    yf = jnp.maximum(yf, 0.0) if relu else yf
    ny = frac_bits(yf, width)
    shift_mult = jnp.exp2(ny - nx - nw)         # 2^-(nx+nw-ny)

    if dims == 1:
        kk = w.shape[0]
        pl_, ph = same_padding(x.shape[1], kk, stride)
        patches, s_out = im2col_1d(xq, kk, stride, pl_, ph)
        m = x.shape[0] * s_out
        acc = fm_kernel.fixed_matmul(
            patches.reshape(m, -1), wq.reshape(-1, w.shape[-1]),
            bacc, shift_mult, width=width, relu=relu,
        )
        yq = acc.reshape(x.shape[0], s_out, w.shape[-1])
    else:
        kh, kw = w.shape[0], w.shape[1]
        pads = (
            same_padding(x.shape[1], kh, stride),
            same_padding(x.shape[2], kw, stride),
        )
        patches, h_out, w_out = im2col_2d(xq, kh, kw, stride, pads)
        m = x.shape[0] * h_out * w_out
        acc = fm_kernel.fixed_matmul(
            patches.reshape(m, -1), wq.reshape(-1, w.shape[-1]),
            bacc, shift_mult, width=width, relu=relu,
        )
        yq = acc.reshape(x.shape[0], h_out, w_out, w.shape[-1])
    return yq * jnp.exp2(-ny)                   # back to real scale


def _qdense(x, w, b, width, use_pallas):
    if width is None:
        return x @ w + b
    if not use_pallas:
        xq = fake_quant(x, width)
        wq = fake_quant(w, width)
        bq = fake_quant(b, width)
        return xq @ wq + bq
    nx = frac_bits(x, width)
    nw = frac_bits(w, width)
    xq = quantize_to_int(x, nx, width)
    wq = quantize_to_int(w, nw, width)
    bacc = jnp.trunc(b * jnp.exp2(nx + nw))
    yf = x @ w + b
    ny = frac_bits(yf, width)
    # Keep logits wide (the final layer feeds argmax, paper keeps it in the
    # layer dtype; we saturate to the same width for parity with the C code).
    acc = fm_kernel.fixed_matmul(
        xq, wq, bacc, jnp.exp2(ny - nx - nw), width=width, relu=False
    )
    return acc * jnp.exp2(-ny)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def apply(params, x, cfg: ModelConfig, width=None, use_pallas: bool = False):
    """ResNetv1-6 forward. width=None → float32; width=8 → QAT fake-quant;
    use_pallas routes conv/dense contractions through the L1 kernels."""
    (c1w, c1b, b1c1w, b1c1b, b1c2w, b1c2b,
     b2c1w, b2c1b, b2c2w, b2c2b, scw, scb, dw, db) = params
    d = cfg.dims

    x = _maybe_fq(x, width, use_pallas)
    h = _qconv(x, c1w, c1b, 1, d, width, use_pallas, relu=True)
    h = _maxpool(h, d)

    # Block 1: identity shortcut
    y = _qconv(h, b1c1w, b1c1b, 1, d, width, use_pallas, relu=True)
    y = _qconv(y, b1c2w, b1c2b, 1, d, width, use_pallas, relu=False)
    h = jnp.maximum(h + y, 0.0)
    h = _maybe_fq(h, width, use_pallas)
    h = _maxpool(h, d)

    # Block 2: stride-2 with 1x1-conv shortcut
    y = _qconv(h, b2c1w, b2c1b, 2, d, width, use_pallas, relu=True)
    y = _qconv(y, b2c2w, b2c2b, 1, d, width, use_pallas, relu=False)
    s = _qconv(h, scw, scb, 2, d, width, use_pallas, relu=False)
    h = jnp.maximum(s + y, 0.0)
    h = _maybe_fq(h, width, use_pallas)

    h = _global_avgpool(h, d)
    return _qdense(h, dw, db, width, use_pallas)


# ---------------------------------------------------------------------------
# Training (paper §6: SGD momentum 0.9, weight decay 5e-4, mixup, z-scored
# inputs; LR schedule is driven from the Rust coordinator via the lr input)
# ---------------------------------------------------------------------------

def _cross_entropy(logits, onehot):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def _mixup(key, x, y_onehot):
    """Mixup (Zhang et al. 2018) with Beta(alpha, alpha)."""
    kl, kp = jax.random.split(key)
    lam = jax.random.beta(kl, MIXUP_ALPHA, MIXUP_ALPHA)
    perm = jax.random.permutation(kp, x.shape[0])
    xm = lam * x + (1.0 - lam) * x[perm]
    ym = lam * y_onehot + (1.0 - lam) * y_onehot[perm]
    return xm, ym


def train_step(params, mom, x, y, key_data, lr, cfg: ModelConfig, width=None):
    """One SGD step. Returns (new_params, new_mom, loss).

    params/mom: lists in PARAM_NAMES order. x: batch inputs. y: int32 labels.
    key_data: uint32[2] PRNG key payload. lr: scalar learning rate.
    width: None for the float phase, 8 for QAT fine-tuning (§4.3).
    """
    key = jax.random.wrap_key_data(key_data.astype(jnp.uint32),
                                   impl="threefry2x32")

    def loss_fn(p):
        y1 = jax.nn.one_hot(y, cfg.classes)
        xm, ym = _mixup(key, x, y1)
        logits = apply(p, xm, cfg, width=width)
        return _cross_entropy(logits, ym)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    new_mom, new_params = [], []
    for p, m, g in zip(params, mom, grads):
        g = g + WEIGHT_DECAY * p
        m2 = MOMENTUM * m + g
        new_mom.append(m2)
        new_params.append(p - lr * m2)
    return new_params, new_mom, loss


def accuracy(params, x, y, cfg: ModelConfig, width=None, use_pallas=False):
    logits = apply(params, x, cfg, width=width, use_pallas=use_pallas)
    return jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))


# ---------------------------------------------------------------------------
# Dataset model configurations (paper §6.1) — shapes only; the data itself
# is synthesized by the Rust coordinator (DESIGN.md §3 substitutions).
# ---------------------------------------------------------------------------

DATASETS = {
    "har": ModelConfig(dims=1, input_shape=(128, 9), classes=6, filters=0),
    "smnist": ModelConfig(dims=1, input_shape=(39, 13), classes=10, filters=0),
    "gtsrb": ModelConfig(dims=2, input_shape=(32, 32, 3), classes=43, filters=0),
}


def make_config(dataset: str, filters: int) -> ModelConfig:
    base = DATASETS[dataset]
    return ModelConfig(
        dims=base.dims,
        input_shape=base.input_shape,
        classes=base.classes,
        filters=filters,
    )
