"""Mirror fuzz of the SIMD GEMM microkernels (DESIGN.md §13).

No Rust toolchain lives in this container, so the `nn::simd` kernel pair
(scalar set vs the AVX2 set dispatched behind the same `KernelSet`
table) is mirrored here in Python/numpy and fuzzed over random
geometry. The mirrors reproduce the semantics that distinguish the two
Rust paths — everything a native test of the real kernels would pin:

- P1  i32 lane: `_mm256_mullo_epi32` / `_mm256_add_epi32` wrap mod 2^32
      exactly like scalar release arithmetic, and the NR-column
      vector grouping plus the ``av == 0`` sparsity skip preserve
      bit-equality — including on accumulators crafted to straddle the
      i32 boundary.
- P2  i64 lanes: the `_mm256_mul_epi32` exactness claim — it multiplies
      the LOW 32 bits of each 64-bit lane (signed 32x32->64); packed
      i64 weights are pre-widened from i32, so the low 32 bits
      sign-extend back to the exact weight and the product is the exact
      i64 product. Fuzzed over the full i32 weight range, then through
      whole-kernel accumulation with the fixed-point rescale/clamp
      epilogue (bit-equality, fixed and affine accumulators).
- P3  tails: column tails (n % NR) read the zero-filled packed lanes at
      full vector width and store only the live columns; row tails
      (m % MR) shrink the tile. Mirrored full-width accumulation over
      the zero-filled panel must equal the scalar valid-columns-only
      walk on every ragged geometry, including j0/j1 sub-windows.
- P4  f32 lane: `_mm256_fmadd_ps` contracts mul+add into ONE rounding.
      Simulated via float64 multiply-add rounded once to float32 per
      MACC step, vs the scalar two-rounding float32 path — must stay
      inside the session-level 1e-4 relative budget on fixture-scaled
      data (and is generally NOT bit-identical, which the suite also
      demonstrates rather than assumes away).

The integer epilogues in the Rust AVX2 kernels spill the accumulator
vectors and run the *scalar* per-element requant code, so accumulator
equality here implies output equality there; the fixed-lane mirrors
still run the full rescale/clamp tail to pin the spilled path end to
end. Mirroring rules: Python ``>>`` on negative ints floors, same as
two's-complement arithmetic shift (see .claude/skills/verify/SKILL.md).
"""

import random

import numpy as np

MR, NR = 4, 8
I32_MIN, I32_MAX = -(1 << 31), (1 << 31) - 1


def wrap32(x):
    return ((x + (1 << 31)) % (1 << 32)) - (1 << 31)


def wrap64(x):
    return ((x + (1 << 63)) % (1 << 64)) - (1 << 63)


def sext_low32(x):
    """Low 32 bits of x, reinterpreted as signed — what _mm256_mul_epi32
    reads from each 64-bit lane."""
    return ((x & 0xFFFFFFFF) ^ 0x80000000) - 0x80000000


def mul_epi32(a, b):
    """Signed 32x32 -> exact 64-bit product of the low halves."""
    return sext_low32(a) * sext_low32(b)


def rescale(acc, shift):
    if shift >= 0:
        return acc >> min(shift, 63)
    return wrap64(acc << min(-shift, 63))


def clamp_to(acc, width):
    lo, hi = -(1 << (width - 1)), (1 << (width - 1)) - 1
    return max(lo, min(hi, acc))


def packed_cols(n):
    return (n + NR - 1) // NR * NR


def pack_b(w, k, n):
    """NR-tiled packed panel of a k x n (taps-major) matrix, tail columns
    zero-filled — the layout pack_panels emits."""
    bp = [0] * (packed_cols(n) * k)
    for t in range((n + NR - 1) // NR):
        tb = t * k * NR
        for p in range(k):
            for jj in range(NR):
                j = t * NR + jj
                bp[tb + p * NR + jj] = w[p * n + j] if j < n else 0
    return bp


def geometry(rng):
    """Random kernel-call geometry incl. ragged tails and sub-windows,
    mirroring nn::simd's unit-test generator."""
    m = rng.randint(1, 9)
    n = rng.randint(1, 20)
    k = rng.randint(1, 17)
    t0 = rng.randrange((n + NR - 1) // NR)
    j0 = t0 * NR
    j1 = rng.randint(j0, n)
    return m, n, k, j0, j1


# ---------------------------------------------------------------------------
# i32 lane (int8 backend): scalar walk vs AVX2-structured walk.
# ---------------------------------------------------------------------------

def kernel_i32_scalar(a, bp, m, n, k, j0, j1, bias, shift, width, relu):
    """Valid-columns-only reference walk (the scalar kernel)."""
    out = {}
    for i in range(m):
        for j in range(j0, j1):
            t, jj = j // NR, j % NR
            tb = t * k * NR
            acc = 0
            for p in range(k):
                av = a[i * k + p]
                if av == 0:
                    continue
                acc = wrap32(acc + wrap32(av * bp[tb + p * NR + jj]))
            fi = j
            total = wrap32(acc + wrap32(bias[fi]))
            sh = shift[fi] if len(shift) > 1 else shift[0]
            v = clamp_to(rescale(total, sh), width)
            out[(i, j)] = max(v, 0) if relu else v
    return out


def kernel_i32_avx2(a, bp, m, n, k, j0, j1, bias, shift, width, relu):
    """Vector-structured walk: full-NR accumulation over the zero-filled
    panel (mullo/add wrap mod 2^32), spill, scalar epilogue on live
    columns only."""
    out = {}
    for i0 in range(0, m, MR):
        mr = min(MR, m - i0)
        for t in range(j0 // NR, (j1 + NR - 1) // NR):
            tb = t * k * NR
            nr = min(NR, j1 - t * NR)
            acc = [[0] * NR for _ in range(mr)]
            for p in range(k):
                brow = bp[tb + p * NR : tb + p * NR + NR]  # full-width load
                for r in range(mr):
                    av = a[(i0 + r) * k + p]
                    if av == 0:
                        continue
                    for c in range(NR):
                        acc[r][c] = wrap32(acc[r][c] + wrap32(av * brow[c]))
            for r in range(mr):
                spill = acc[r]  # _mm256_storeu_si256 into [i32; NR]
                for c in range(nr):
                    fi = t * NR + c
                    total = wrap32(spill[c] + wrap32(bias[fi]))
                    sh = shift[fi] if len(shift) > 1 else shift[0]
                    v = clamp_to(rescale(total, sh), width)
                    out[(i0 + r, fi)] = max(v, 0) if relu else v
    return out


def test_i32_lane_bit_exact_incl_wrap():
    rng = random.Random(101)
    for case in range(150):
        m, n, k, j0, j1 = geometry(rng)
        relu = rng.random() < 0.5
        lim = 127
        a = [rng.randint(-lim, lim) if rng.random() > 0.15 else 0 for _ in range(m * k)]
        w = [rng.randint(-lim, lim) for _ in range(k * n)]
        # Bias crafted to push some accumulators across the i32 boundary
        # so the wrap semantics themselves are exercised, not just small
        # sums (the Rust verifier keeps admitted nodes away from the
        # boundary; the KERNELS must still agree bit-for-bit past it).
        boundary = (1 << 31) - k * lim * lim
        bias = [
            rng.choice([rng.randint(-(1 << 12), 1 << 12),
                        wrap32(boundary + rng.randint(-1024, 1024))])
            for _ in range(n)
        ]
        shift = [rng.randint(0, 14) for _ in range(n)] if rng.random() < 0.5 else [7]
        bp = pack_b(w, k, n)
        sc = kernel_i32_scalar(a, bp, m, n, k, j0, j1, bias, shift, 8, relu)
        vx = kernel_i32_avx2(a, bp, m, n, k, j0, j1, bias, shift, 8, relu)
        assert sc == vx, f"i32 lane diverged on case {case} (m={m} n={n} k={k} j0={j0} j1={j1})"


# ---------------------------------------------------------------------------
# i64 lanes (int16 fixed + affine accumulators): _mm256_mul_epi32 claim.
# ---------------------------------------------------------------------------

def test_mul_epi32_exact_on_prewidened_weights():
    rng = random.Random(202)
    for _ in range(4000):
        av = rng.randint(I32_MIN, I32_MAX)   # broadcast activation (i64 lane)
        w = rng.randint(I32_MIN, I32_MAX)    # weight pre-widened i32 -> i64
        lane_a = wrap64(av)                  # _mm256_set1_epi64x(av as i64)
        lane_b = wrap64(w)                   # packed i64 weight
        assert mul_epi32(lane_a, lane_b) == av * w, (
            f"_mm256_mul_epi32 model diverged: av={av} w={w}"
        )
    # Edge pins: the claim is exactly "low 32 bits sign-extend back".
    for av, w in [(I32_MIN, I32_MIN), (I32_MIN, I32_MAX), (-1, I32_MIN),
                  (I32_MAX, I32_MAX), (0, I32_MIN)]:
        assert mul_epi32(wrap64(av), wrap64(w)) == av * w


def kernel_i64_scalar(a, bp, m, k, j0, j1, bias, shift, width):
    out = {}
    for i in range(m):
        for j in range(j0, j1):
            t, jj = j // NR, j % NR
            tb = t * k * NR
            acc = 0
            for p in range(k):
                av = a[i * k + p]
                if av == 0:
                    continue
                acc = wrap64(acc + av * bp[tb + p * NR + jj])
            total = wrap64(acc + bias[j])
            sh = shift[j] if len(shift) > 1 else shift[0]
            out[(i, j)] = clamp_to(rescale(total, sh), width)
    return out


def kernel_i64_avx2(a, bp, m, k, j0, j1, bias, shift, width):
    """acc_lo/acc_hi pairs (4+4 columns), mul_epi32 products, full-width
    loads over the zero-filled panel, dual-storeu spill, scalar tail."""
    out = {}
    for i0 in range(0, m, MR):
        mr = min(MR, m - i0)
        for t in range(j0 // NR, (j1 + NR - 1) // NR):
            tb = t * k * NR
            nr = min(NR, j1 - t * NR)
            acc_lo = [[0] * 4 for _ in range(mr)]
            acc_hi = [[0] * 4 for _ in range(mr)]
            for p in range(k):
                b_lo = bp[tb + p * NR : tb + p * NR + 4]
                b_hi = bp[tb + p * NR + 4 : tb + p * NR + 8]
                for r in range(mr):
                    av = a[(i0 + r) * k + p]
                    if av == 0:
                        continue
                    avv = wrap64(av)  # set1_epi64x
                    for c in range(4):
                        acc_lo[r][c] = wrap64(acc_lo[r][c] + mul_epi32(avv, b_lo[c]))
                        acc_hi[r][c] = wrap64(acc_hi[r][c] + mul_epi32(avv, b_hi[c]))
            for r in range(mr):
                spill = acc_lo[r] + acc_hi[r]  # two storeu into [i64; NR]
                for c in range(nr):
                    fi = t * NR + c
                    total = wrap64(spill[c] + bias[fi])
                    sh = shift[fi] if len(shift) > 1 else shift[0]
                    out[(i0 + r, fi)] = clamp_to(rescale(total, sh), width)
    return out


def test_i64_lane_bit_exact_fixed_and_affine_accumulators():
    rng = random.Random(303)
    for case in range(150):
        m, n, k, j0, j1 = geometry(rng)
        width = rng.choice([8, 16])
        lim = (1 << (width - 1)) - 1
        a = [rng.randint(-lim, lim) if rng.random() > 0.15 else 0 for _ in range(m * k)]
        # Pre-widened weights: i32 values stored in i64 panel lanes. Use
        # the full i32 range — far beyond what quantization emits — so
        # the low-32 sign-extension claim is stressed, not grazed.
        w = [rng.choice([rng.randint(-lim, lim),
                         rng.randint(I32_MIN, I32_MAX)]) for _ in range(k * n)]
        bias = [rng.randint(-(1 << 40), 1 << 40) for _ in range(n)]
        shift = [rng.randint(0, 30) for _ in range(n)] if rng.random() < 0.5 else [width - 1]
        bp = [wrap64(x) for x in pack_b(w, k, n)]
        sc = kernel_i64_scalar(a, bp, m, k, j0, j1, bias, shift, width)
        vx = kernel_i64_avx2(a, bp, m, k, j0, j1, bias, shift, width)
        assert sc == vx, f"i64 lane diverged on case {case} (m={m} n={n} k={k} j0={j0} j1={j1})"


# ---------------------------------------------------------------------------
# f32 lane: FMA single-rounding vs scalar two-rounding.
# ---------------------------------------------------------------------------

def f32_scalar(a, bp, m, k, j0, j1, bias, relu):
    """float32 mul, then float32 add — two roundings per MACC step."""
    out = np.zeros((m, j1), dtype=np.float32)
    for i in range(m):
        for j in range(j0, j1):
            t, jj = j // NR, j % NR
            tb = t * k * NR
            acc = np.float32(0.0)
            for p in range(k):
                prod = np.float32(np.float32(a[i * k + p]) * np.float32(bp[tb + p * NR + jj]))
                acc = np.float32(acc + prod)
            v = np.float32(acc + np.float32(bias[j]))
            out[i, j] = max(v, np.float32(0.0)) if relu else v
    return out


def f32_fma(a, bp, m, k, j0, j1, bias, relu):
    """float64 multiply-add rounded ONCE to float32 per step — the
    _mm256_fmadd_ps contraction (float64 holds the exact f32 product, so
    the single float32 rounding of (prod + acc) models fused behavior)."""
    out = np.zeros((m, j1), dtype=np.float32)
    for i in range(m):
        for j in range(j0, j1):
            t, jj = j // NR, j % NR
            tb = t * k * NR
            acc = np.float32(0.0)
            for p in range(k):
                acc = np.float32(
                    np.float64(a[i * k + p]) * np.float64(bp[tb + p * NR + jj])
                    + np.float64(acc)
                )
            v = np.float32(acc + np.float32(bias[j]))
            out[i, j] = max(v, np.float32(0.0)) if relu else v
    return out


def test_f32_fma_within_session_budget_not_bitwise():
    rng = random.Random(404)
    any_bits_moved = False
    for case in range(60):
        m = rng.randint(1, 6)
        n = rng.randint(1, 16)
        k = rng.randint(8, 96)  # deep enough for contraction to show
        j0, j1 = 0, n
        a = [rng.gauss(0.0, 1.0) for _ in range(m * k)]
        w = [rng.gauss(0.0, 0.35) for _ in range(k * n)]
        bias = [rng.gauss(0.0, 0.05) for _ in range(n)]
        relu = rng.random() < 0.5
        bp = pack_b_f32(w, k, n)
        sc = f32_scalar(a, bp, m, k, j0, j1, bias, relu)
        fm = f32_fma(a, bp, m, k, j0, j1, bias, relu)
        tol = np.maximum(np.float32(1e-4), np.abs(sc) * np.float32(1e-4))
        assert np.all(np.abs(sc - fm) <= tol), (
            f"f32 FMA left the 1e-4 relative budget on case {case} "
            f"(max delta {np.max(np.abs(sc - fm))})"
        )
        if sc.tobytes() != fm.tobytes():
            any_bits_moved = True
    # The budget is needed, not paranoia: contraction really moves bits.
    assert any_bits_moved, "FMA simulation never moved a bit — model is wrong"


def pack_b_f32(w, k, n):
    bp = [0.0] * (packed_cols(n) * k)
    for t in range((n + NR - 1) // NR):
        tb = t * k * NR
        for p in range(k):
            for jj in range(NR):
                j = t * NR + jj
                bp[tb + p * NR + jj] = w[p * n + j] if j < n else 0.0
    return bp


# ---------------------------------------------------------------------------
# Tail zero-fill: the property that makes full-width B loads sound.
# ---------------------------------------------------------------------------

def test_packed_tail_columns_are_zero_and_inert():
    rng = random.Random(505)
    for _ in range(60):
        n = rng.randint(1, 20)
        k = rng.randint(1, 17)
        w = [rng.randint(-127, 127) for _ in range(k * n)]
        bp = pack_b(w, k, n)
        assert len(bp) == packed_cols(n) * k
        for p in range(k):
            last = (packed_cols(n) // NR - 1) * k * NR
            for jj in range(NR):
                j = (packed_cols(n) - NR) + jj
                lane = bp[last + p * NR + jj]
                if j >= n:
                    assert lane == 0, "tail lane not zero-filled"
        # Inert: accumulating the dead lanes at full width never changes
        # a live column (they contribute to lanes that are never stored),
        # which P1/P3 already verify end to end; here we pin the layout
        # invariant those proofs rest on.
