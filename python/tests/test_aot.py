"""AOT artifact tests: HLO text is generated, parseable-looking, and the
manifest is consistent with the model configs."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as M

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_roundtrip_smell():
    def fn(x):
        return (x * 2.0 + 1.0,)

    text = aot.to_hlo_text(jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32)))
    assert "HloModule" in text
    assert "ENTRY" in text
    # return_tuple=True: the root must be a tuple
    assert "tuple(" in text or "(f32[4]" in text


def test_manifest_exists_and_consistent():
    path = os.path.join(ARTIFACT_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as fh:
        manifest = json.load(fh)
    assert manifest["version"] == 1
    assert manifest["models"], "no models in manifest"
    for tag, entry in manifest["models"].items():
        cfg = M.make_config(entry["dataset"], entry["filters"])
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        assert entry["param_names"] == M.PARAM_NAMES
        assert [list(p.shape) for p in params] == entry["param_shapes"], tag
        for art in entry["artifacts"].values():
            apath = os.path.join(ARTIFACT_DIR, art)
            assert os.path.exists(apath), apath
            with open(apath) as fh:
                head = fh.read(200)
            assert "HloModule" in head, apath


def test_kernel_artifact_present():
    path = os.path.join(ARTIFACT_DIR, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as fh:
        manifest = json.load(fh)
    k = manifest["kernels"]["fixed_matmul"]
    assert os.path.exists(os.path.join(ARTIFACT_DIR, k["file"]))
    assert (k["m"], k["k"], k["n"]) == (32, 24, 16)


def test_sweeps_cover_paper_datasets():
    assert set(aot.SWEEPS) == {"har", "smnist", "gtsrb"}
    for f_list in aot.SWEEPS.values():
        assert f_list == sorted(f_list)
