"""Hypothesis sweeps: Pallas kernels (L1) vs pure-jnp oracles (ref.py).

This is the CORE correctness signal for the build-time compute path: if
these pass, the HLO artifacts produced by aot.py carry the same integer
semantics the Rust engine implements.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import fake_quant as fqk
from compile.kernels import fixed_matmul as fmk
from compile.kernels import ref
from compile.kernels.quant_math import frac_bits, qmn_limits, quantize_to_int

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# fake_quant kernel
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n_elems=st.integers(1, 5000),
    width=st.sampled_from([8, 9, 16]),
    nbits=st.integers(-2, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_fake_quant_kernel_vs_ref(n_elems, width, nbits, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (n_elems,), scale=3.0)
    scale = jnp.float32(2.0**nbits)
    got = fqk.fake_quant(x, scale, width)
    want = ref.fake_quant_with_scale_ref(x, scale, width)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)


@settings(**SETTINGS)
@given(
    shape=st.sampled_from([(3,), (4, 5), (2, 3, 4), (2, 3, 4, 5)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fake_quant_kernel_preserves_shape(shape, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, shape)
    got = fqk.fake_quant(x, jnp.float32(64.0), 8)
    assert got.shape == shape


# ---------------------------------------------------------------------------
# fixed_matmul kernel
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 96),
    n=st.integers(1, 160),
    shift=st.integers(0, 10),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_fixed_matmul_kernel_vs_ref(m, k, n, shift, relu, seed):
    rng = np.random.default_rng(seed)
    lo, hi = qmn_limits(8)
    xq = jnp.asarray(rng.integers(lo, hi + 1, size=(m, k)).astype(np.float32))
    wq = jnp.asarray(rng.integers(lo, hi + 1, size=(k, n)).astype(np.float32))
    bq = jnp.asarray(rng.integers(-(1 << 12), 1 << 12, size=(n,)).astype(np.float32))
    mult = jnp.float32(2.0**-shift)
    got = fmk.fixed_matmul(xq, wq, bq, mult, width=8, relu=relu)
    want = ref.fixed_matmul_bias_ref(xq, wq, bq, mult, 8, relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fixed_matmul_saturates_exactly():
    # A single huge accumulator must clamp to +127 / -128.
    xq = jnp.full((1, 4), 127.0)
    wq = jnp.full((4, 2), 127.0).at[:, 1].set(-128.0)
    bq = jnp.zeros((2,))
    got = fmk.fixed_matmul(xq, wq, bq, jnp.float32(1.0), width=8, relu=False)
    assert got.tolist() == [[127.0, -128.0]]


# ---------------------------------------------------------------------------
# im2col helpers (used by the qfwd8 artifacts)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    s=st.integers(4, 64),
    c=st.integers(1, 8),
    f=st.integers(1, 8),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_im2col_conv1d_matches_lax(s, c, f, stride, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (2, s, c))
    w = _rand(rng, (3, c, f))
    pl_, ph = ref.same_padding(s, 3, stride)
    patches, s_out = ref.im2col_1d(x, 3, stride, pl_, ph)
    got = (patches.reshape(2 * s_out, -1) @ w.reshape(-1, f)).reshape(2, s_out, f)
    want = jax.lax.conv_general_dilated(
        x, w, (stride,), "SAME", dimension_numbers=("NWC", "WIO", "NWC")
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(4, 20),
    c=st.integers(1, 4),
    f=st.integers(1, 6),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_im2col_conv2d_matches_lax(h, c, f, stride, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (2, h, h, c))
    w = _rand(rng, (3, 3, c, f))
    pads = (ref.same_padding(h, 3, stride), ref.same_padding(h, 3, stride))
    patches, ho, wo = ref.im2col_2d(x, 3, 3, stride, pads)
    got = (patches.reshape(2 * ho * wo, -1) @ w.reshape(-1, f)).reshape(2, ho, wo, f)
    want = jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# accumulator exactness precondition (DESIGN: |acc| < 2^24 for int8)
# ---------------------------------------------------------------------------

def test_accumulator_exactness_bound():
    # Largest contraction in the artifact sweep: k=3 taps * 80 ch = 240.
    k = 240
    worst = k * 128 * 128 + (1 << 13)
    assert worst < 2**24, "int8 accumulation must stay exact in f32"
