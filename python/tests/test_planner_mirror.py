"""Mirror fuzz of the verified offset-based memory planner (DESIGN.md §12).

No Rust toolchain lives in this container, so the planner/checker pair in
``rust/src/allocator`` (exact liveness -> in-place classes -> host
first-fit -> best-fit-decreasing offsets, re-proven by the trusted
byte-range checker) is mirrored 1:1 in pure Python here and fuzzed over
random synthetic DAGs:

- P1  the mirrored checker accepts every mirrored planner output;
- P2  planned arena <= pooled baseline on every graph (never-worse);
- P3  a crafted overlapping plan (consumer parked on its live producer's
      offset without the in-place sanction) is refused;
- P4  layout soundness by *simulation*: replaying unique per-node tokens
      through the planned offsets, every read a node performs still
      observes its producer's token — this would catch a planner AND
      checker agreeing on something unsound;
- P5  the in-place kernel twins (add / softmax / embedding descending
      gather, incl. the batched flat walk) are bit-identical to their
      out-of-place references under aliasing.

Mirroring rules that matter (see .claude/skills/verify/SKILL.md):
``rescale`` is a plain arithmetic shift (Python ``>>`` on negative ints
floors, same as two's-complement ``>>``); integer division in the
softmax normalize pass TRUNCATES toward zero in Rust/C (``tdiv`` below,
not Python ``//``).
"""

import random

INF = 1 << 60  # usize::MAX stand-in (never added to, only compared)


# ---------------------------------------------------------------------------
# Synthetic graph: list of dicts {kind, inputs, elems, d?}. Node ids are
# list indices == the topological schedule, like the Rust IR.
# ---------------------------------------------------------------------------

def node(kind, inputs, elems, d=1):
    return {"kind": kind, "inputs": inputs, "elems": elems, "d": d}


INPLACE_KINDS = ("add", "relu", "softmax", "flatten", "embedding")


def random_graph(rng):
    """Random DAG over the planner-relevant kinds, input first, single
    output (the last node), every node reachable as someone's input or
    the output."""
    nodes = [node("input", [], rng.randint(4, 64))]
    n_body = rng.randint(3, 14)
    for _ in range(n_body):
        nid = len(nodes)
        kind = rng.choice(
            ["generic", "generic", "generic", "add", "relu", "softmax",
             "flatten", "embedding", "attention"]
        )
        src = rng.randrange(nid)
        if kind == "add":
            peers = [i for i in range(nid) if nodes[i]["elems"] == nodes[src]["elems"]]
            other = rng.choice(peers)
            nodes.append(node("add", [src, other], nodes[src]["elems"]))
        elif kind in ("relu", "softmax", "flatten"):
            nodes.append(node(kind, [src], nodes[src]["elems"]))
        elif kind == "embedding":
            d = rng.randint(1, 6)
            nodes.append(node("embedding", [src], nodes[src]["elems"] * d, d))
        elif kind == "attention":
            # window size == out elems (seq * d_model), like the Rust IR
            nodes.append(node("attention", [src], nodes[src]["elems"]))
        else:
            nodes.append(node("generic", [src], rng.randint(4, 96)))
    return nodes


# ---------------------------------------------------------------------------
# Mirror of analysis::liveness + allocator::planner
# ---------------------------------------------------------------------------

def last_use(nodes):
    last = list(range(len(nodes)))
    for nid, nd in enumerate(nodes):
        for i in nd["inputs"]:
            last[i] = max(last[i], nid)
    last[len(nodes) - 1] = INF  # graph output read by the caller forever
    return last


def inplace_candidate(nodes, last, nid):
    nd = nodes[nid]

    def legal(i, grow):
        return (
            nodes[i]["kind"] != "input"
            and last[i] == nid
            and nodes[i]["elems"] * grow == nd["elems"]
        )

    if nd["kind"] == "add":
        if nd["inputs"][0] == nd["inputs"][1]:
            return None
        for i in nd["inputs"]:
            if legal(i, 1):
                return i
        return None
    if nd["kind"] in ("relu", "softmax", "flatten"):
        i = nd["inputs"][0]
        return i if legal(i, 1) else None
    if nd["kind"] == "embedding":
        i = nd["inputs"][0]
        return i if legal(i, nd["d"]) else None
    return None


def bfd_offsets(chunks):
    """chunks: list of dicts {elems, birth, death, members, window}."""
    def tie(c):
        if c["members"]:
            return c["members"][0]
        if c["window"] is not None:
            return c["window"][0] * 4 + c["window"][1]
        return 0

    order = sorted(range(len(chunks)),
                   key=lambda i: (-chunks[i]["elems"], chunks[i]["birth"], tie(chunks[i])))
    offsets = [0] * len(chunks)
    placed = []
    arena = 0
    for i in order:
        ci = chunks[i]
        live = [j for j in placed
                if ci["birth"] <= chunks[j]["death"] and chunks[j]["birth"] <= ci["death"]]
        candidates = sorted({0} | {offsets[j] + chunks[j]["elems"] for j in live})
        off = next(c for c in candidates
                   if all(c + ci["elems"] <= offsets[j]
                          or offsets[j] + chunks[j]["elems"] <= c for j in live))
        offsets[i] = off
        arena = max(arena, off + ci["elems"])
        placed.append(i)
    return offsets, arena


def pooled_first_fit(nodes, last):
    n = len(nodes)
    pool_of = [INF] * n
    pool_elems = []
    occupant = []
    for nid, nd in enumerate(nodes):
        if nd["kind"] == "input":
            continue
        chosen = None
        for p, occ in enumerate(occupant):
            if occ is None:
                chosen = p
                break
            still_needed = last[occ] > nid
            is_my_input = any(pool_of[i] == p for i in nd["inputs"])
            if not still_needed and not is_my_input:
                chosen = p
                break
        if chosen is None:
            occupant.append(None)
            pool_elems.append(0)
            chosen = len(occupant) - 1
        pool_of[nid] = chosen
        occupant[chosen] = nid
        pool_elems[chosen] = max(pool_elems[chosen], nd["elems"])
    return pool_of, pool_elems


def plan(nodes):
    n = len(nodes)
    last = last_use(nodes)

    inplace_with = [None] * n
    class_root = list(range(n))
    for nid in range(n):
        s = inplace_candidate(nodes, last, nid)
        if s is not None:
            inplace_with[nid] = s
            class_root[nid] = class_root[s]

    chunks = []
    chunk_of_root = [None] * n
    for nid, nd in enumerate(nodes):
        if nd["kind"] == "input":
            continue
        root = class_root[nid]
        if chunk_of_root[root] is None:
            chunk_of_root[root] = len(chunks)
            chunks.append({"elems": 0, "birth": nid, "death": max(last[nid], nid),
                           "members": [], "window": None})
        c = chunks[chunk_of_root[root]]
        c["elems"] = max(c["elems"], nd["elems"])
        c["birth"] = min(c["birth"], nid)
        c["death"] = max(c["death"], max(last[nid], nid))
        c["members"].append(nid)
    n_classes = len(chunks)

    pool_of = [INF] * n
    pool_elems = []
    slot_tenants = []
    for ci in range(n_classes):
        cc = chunks[ci]

        def free(tenants):
            return all(not (cc["birth"] <= chunks[t]["death"]
                            and chunks[t]["birth"] <= cc["death"]) for t in tenants)

        slot = next((s for s, t in enumerate(slot_tenants) if free(t)), None)
        if slot is None:
            slot_tenants.append([])
            pool_elems.append(0)
            slot = len(slot_tenants) - 1
        slot_tenants[slot].append(ci)
        pool_elems[slot] = max(pool_elems[slot], cc["elems"])
        for m in cc["members"]:
            pool_of[m] = slot

    for nid, nd in enumerate(nodes):
        if nd["kind"] == "attention":
            for k in range(4):
                chunks.append({"elems": nd["elems"], "birth": nid, "death": nid,
                               "members": [], "window": (nid, k)})
    chunk_off, arena_elems = bfd_offsets(chunks)
    offset_of = [INF] * n
    attn_scratch_of = [None] * n
    for ci, c in enumerate(chunks):
        for m in c["members"]:
            offset_of[m] = chunk_off[ci]
        if c["window"] is not None:
            nid, k = c["window"]
            if attn_scratch_of[nid] is None:
                attn_scratch_of[nid] = [0, 0, 0, 0]
            attn_scratch_of[nid][k] = chunk_off[ci]

    pool_of_57, pool_elems_57 = pooled_first_fit(nodes, last)
    attn_total = sum(4 * nd["elems"] for nd in nodes if nd["kind"] == "attention")
    pooled_elems = sum(pool_elems_57) + attn_total

    alloc = {"pool_of": pool_of, "pool_elems": pool_elems,
             "inplace_with": inplace_with, "offset_of": offset_of,
             "arena_elems": arena_elems, "pooled_elems": pooled_elems,
             "attn_scratch_of": attn_scratch_of}

    if arena_elems > pooled_elems:  # never-worse fallback
        base, acc = [0] * len(pool_elems_57), 0
        for p, e in enumerate(pool_elems_57):
            base[p] = acc
            acc += e
        alloc["offset_of"] = [INF if p == INF else base[p] for p in pool_of_57]
        scratch = [None] * n
        for nid, nd in enumerate(nodes):
            if nd["kind"] == "attention":
                sd = nd["elems"]
                scratch[nid] = [acc, acc + sd, acc + 2 * sd, acc + 3 * sd]
                acc += 4 * sd
        alloc["attn_scratch_of"] = scratch
        alloc["pool_of"] = pool_of_57
        alloc["pool_elems"] = pool_elems_57
        alloc["inplace_with"] = [None] * n
        alloc["arena_elems"] = pooled_elems
    return alloc


# ---------------------------------------------------------------------------
# Mirror of allocator::check_no_conflict (the trusted side)
# ---------------------------------------------------------------------------

def check_no_conflict(nodes, alloc):
    n = len(nodes)
    last = last_use(nodes)
    elems = [nd["elems"] for nd in nodes]

    def death(i):
        return max(last[i], i)

    def lives_at(i, t):
        return i <= t <= death(i)

    def temporal(i, j):
        return i <= death(j) and j <= death(i)

    def disjoint(o1, e1, o2, e2):
        return o1 + e1 <= o2 or o2 + e2 <= o1

    host_base, acc = [0] * len(alloc["pool_elems"]), 0
    for p, e in enumerate(alloc["pool_elems"]):
        host_base[p] = acc
        acc += e

    for nid, nd in enumerate(nodes):
        if nd["kind"] == "input":
            if alloc["pool_of"][nid] != INF or alloc["offset_of"][nid] != INF:
                return f"caller-owned Input {nid} must not be planned"
            if alloc["inplace_with"][nid] is not None:
                return f"Input {nid} cannot be in-place"
            continue
        p = alloc["pool_of"][nid]
        if p == INF or p >= len(alloc["pool_elems"]):
            return f"node {nid} has no host slot"
        if alloc["pool_elems"][p] < elems[nid]:
            return f"node {nid} undersized host slot"
        off = alloc["offset_of"][nid]
        if off == INF or off + elems[nid] > alloc["arena_elems"]:
            return f"node {nid} escapes the arena"
        for i in nd["inputs"]:
            if i >= nid:
                return f"node {nid} reads {i} out of schedule order"
            if not lives_at(i, nid):
                return f"node {nid} reads {i} after its death"
        s = alloc["inplace_with"][nid]
        if s is not None:
            if s not in nd["inputs"]:
                return f"node {nid} claims in-place over non-input {s}"
            if nodes[s]["kind"] == "input":
                return f"node {nid} may not overwrite the caller's input"
            if last[s] != nid:
                return f"node {nid} overwrites {s} while still read"
            if nd["kind"] == "add":
                ok = nd["inputs"][0] != nd["inputs"][1] and elems[nid] == elems[s]
            elif nd["kind"] in ("relu", "softmax", "flatten"):
                ok = elems[nid] == elems[s]
            elif nd["kind"] == "embedding":
                ok = elems[nid] == elems[s] * nd["d"]
            else:
                return f"node {nid} is not an alias-safe in-place kind"
            if not ok:
                return f"node {nid} in-place size rule violated"
            if alloc["offset_of"][s] != off or alloc["pool_of"][s] != p:
                return f"in-place node {nid} does not alias {s} exactly"
        w = alloc["attn_scratch_of"][nid]
        if nd["kind"] == "attention":
            if w is None:
                return f"attention node {nid} lacks stage windows"
            sd = nd["elems"]
            for k, wo in enumerate(w):
                if wo + sd > alloc["arena_elems"]:
                    return f"attention window {k} of {nid} escapes arena"
                for k2 in range(k + 1, 4):
                    if not disjoint(wo, sd, w[k2], sd):
                        return f"attention windows {k}/{k2} of {nid} overlap"
                for o, od in enumerate(nodes):
                    if od["kind"] == "input" or not lives_at(o, nid):
                        continue
                    if not disjoint(wo, sd, alloc["offset_of"][o], elems[o]):
                        return f"attention window {k} of {nid} overlaps live node {o}"
        elif w is not None:
            return f"non-attention node {nid} carries stage windows"

    for i in range(n):
        if nodes[i]["kind"] == "input":
            continue
        for j in range(i + 1, n):
            if nodes[j]["kind"] == "input" or not temporal(i, j):
                continue
            if alloc["inplace_with"][j] == i:
                continue
            if not disjoint(alloc["offset_of"][i], elems[i],
                            alloc["offset_of"][j], elems[j]):
                return f"nodes {i} and {j} overlap in the arena"
            hi, hj = host_base[alloc["pool_of"][i]], host_base[alloc["pool_of"][j]]
            if not disjoint(hi, elems[i], hj, elems[j]):
                return f"nodes {i} and {j} share host slot bytes"
    return None


# ---------------------------------------------------------------------------
# P1/P2: planner output verifies; planned <= pooled
# ---------------------------------------------------------------------------

def test_planner_passes_checker_and_never_loses_to_pools():
    rng = random.Random(901)
    for trial in range(500):
        nodes = random_graph(rng)
        alloc = plan(nodes)
        err = check_no_conflict(nodes, alloc)
        assert err is None, f"trial {trial}: {err}\n{nodes}"
        assert alloc["arena_elems"] <= alloc["pooled_elems"], (
            f"trial {trial}: planned {alloc['arena_elems']} > "
            f"pooled {alloc['pooled_elems']}"
        )


# ---------------------------------------------------------------------------
# P3: crafted overlap refused
# ---------------------------------------------------------------------------

def test_checker_rejects_crafted_overlap():
    rng = random.Random(902)
    rejected = 0
    for _ in range(300):
        nodes = random_graph(rng)
        alloc = plan(nodes)
        victim = next(
            (nid for nid, nd in enumerate(nodes)
             if nd["kind"] != "input" and alloc["inplace_with"][nid] is None
             and any(alloc["offset_of"][i] != INF for i in nd["inputs"])),
            None,
        )
        if victim is None:
            continue
        src = next(i for i in nodes[victim]["inputs"] if alloc["offset_of"][i] != INF)
        evil = dict(alloc)
        evil["offset_of"] = list(alloc["offset_of"])
        evil["offset_of"][victim] = alloc["offset_of"][src]
        err = check_no_conflict(nodes, evil)
        assert err is not None, f"overlap on {victim}/{src} not refused: {nodes}"
        rejected += 1
    assert rejected > 100, "fuzz never exercised the overlap recipe"


# ---------------------------------------------------------------------------
# P4: soundness by simulation — every read observes its producer's token
# ---------------------------------------------------------------------------

def test_layout_simulation_every_read_sees_its_producer():
    rng = random.Random(903)
    for trial in range(300):
        nodes = random_graph(rng)
        alloc = plan(nodes)
        assert check_no_conflict(nodes, alloc) is None
        arena = [None] * alloc["arena_elems"]
        token = lambda nid, k: (nid, k)  # unique per node and element

        def assert_inputs(nid, when):
            for i in nodes[nid]["inputs"]:
                off = alloc["offset_of"][i]
                if off == INF:
                    continue  # caller-owned input buffer
                for k in range(nodes[i]["elems"]):
                    assert arena[off + k] == token(i, k), (
                        f"trial {trial}: node {nid} reads {i} elem {k} "
                        f"clobbered ({when})\n{nodes}"
                    )

        for nid, nd in enumerate(nodes):
            if nd["kind"] == "input":
                continue
            assert_inputs(nid, "before execute")
            if alloc["attn_scratch_of"][nid] is not None:
                # the attention kernel fills q/k/v/ctx while reading x
                for wo in alloc["attn_scratch_of"][nid]:
                    for k in range(nd["elems"]):
                        arena[wo + k] = "garbage"
                assert_inputs(nid, "after stage windows")
            off = alloc["offset_of"][nid]
            for k in range(nd["elems"]):
                arena[off + k] = token(nid, k)
        out = len(nodes) - 1
        off = alloc["offset_of"][out]
        for k in range(nodes[out]["elems"]):
            assert arena[off + k] == token(out, k), "output clobbered"


# ---------------------------------------------------------------------------
# P5: in-place kernel twins bit-identical under aliasing
# (mirrors nn::int_ops — rescale = arithmetic shift, tdiv = C division)
# ---------------------------------------------------------------------------

def clamp_to(acc, width):
    lo, hi = -(1 << (width - 1)), (1 << (width - 1)) - 1
    return max(lo, min(hi, acc))


def rescale(acc, shift):
    return acc >> min(shift, 63) if shift >= 0 else acc << min(-shift, 63)


def tdiv(a, b):
    q = abs(a) // abs(b)
    return q if (a >= 0) == (b >= 0) else -q


def exp_stub(dist, n_in):
    # Deterministic positive stand-in for the Q0.15 exp LUT: the twin
    # equality below holds for ANY pure function here (LUT accuracy is
    # pinned separately since PR 6).
    return ((dist * 2654435761 + n_in) % 32000) + 1


def add_q(a, n_a, b, n_b, n_out, relu, width):
    out = []
    for x, y in zip(a, b):
        v = clamp_to(rescale(x, n_a - n_out) + rescale(y, n_b - n_out), width)
        out.append(max(v, 0) if relu else v)
    return out


def add_q_inplace(acc, n_acc, other, n_other, n_out, relu, width):
    for t in range(len(acc)):
        v = clamp_to(rescale(acc[t], n_acc - n_out) + rescale(other[t], n_other - n_out),
                     width)
        acc[t] = max(v, 0) if relu else v


def softmax_q_row(x, n_in, n_out, width):
    m = max(x) if x else 0
    es = [exp_stub(m - v, n_in) for v in x]
    s = sum(es)
    return [clamp_to(tdiv(e << n_out, s), width) for e in es]


def softmax_q_inplace(x, n_in, n_out, width):
    m = max(x) if x else 0
    s = 0
    for t in range(len(x)):
        x[t] = exp_stub(m - x[t], n_in)
        s += x[t]
    for t in range(len(x)):
        x[t] = clamp_to(tdiv(x[t] << n_out, s), width)


def embedding_q(ids, table, d):
    vocab = len(table) // d
    out = []
    for i in ids:
        i = max(0, min(vocab - 1, i))
        out.extend(table[i * d:(i + 1) * d])
    return out


def embedding_q_inplace(buf, table, d):
    vocab = len(table) // d
    n = len(buf)
    buf.extend([0] * (n * d - n))
    for t in range(n - 1, -1, -1):
        i = max(0, min(vocab - 1, buf[t]))
        buf[t * d:(t + 1) * d] = table[i * d:(i + 1) * d]


def test_inplace_kernel_twins_bit_identical():
    rng = random.Random(904)
    for _ in range(400):
        width = rng.choice((8, 16))
        lim = (1 << (width - 1)) - 1
        n = rng.randint(1, 40)
        payload = lambda: [rng.randint(-lim - 1, lim) for _ in range(n)]

        # add: both aliasing orders reproduce the out-of-place kernel
        a, b = payload(), payload()
        n_a, n_b, n_out = (rng.randint(0, width - 1) for _ in range(3))
        relu = rng.random() < 0.5
        ref = add_q(a, n_a, b, n_b, n_out, relu, width)
        acc = list(a)
        add_q_inplace(acc, n_a, b, n_b, n_out, relu, width)
        assert acc == ref, "add aliased over operand 0 diverged"
        acc = list(b)
        add_q_inplace(acc, n_b, a, n_a, n_out, relu, width)
        assert acc == ref, "add aliased over operand 1 diverged"

        # softmax: 3-pass in-place == two-buffer kernel
        x = payload()
        n_in, sm_out = rng.randint(0, width - 1), width - 1
        ref = softmax_q_row(x, n_in, sm_out, width)
        buf = list(x)
        softmax_q_inplace(buf, n_in, sm_out, width)
        assert buf == ref, "softmax in-place diverged"

        # embedding: descending gather == forward out-of-place, and the
        # batched flat walk over an example-major concatenation is the
        # per-example gather verbatim
        d = rng.randint(1, 5)
        vocab = rng.randint(1, 9)
        table = [rng.randint(-lim - 1, lim) for _ in range(vocab * d)]
        ids = [rng.randint(-1, vocab) for _ in range(rng.randint(1, 12))]
        ref = embedding_q(ids, table, d)
        buf = list(ids)
        embedding_q_inplace(buf, table, d)
        assert buf == ref, "embedding descending gather diverged"
        batch = rng.randint(2, 4)
        flat = [rng.randint(-1, vocab) for _ in range(batch * len(ids))]
        per_example = []
        for e in range(batch):
            per_example.extend(embedding_q(flat[e * len(ids):(e + 1) * len(ids)], table, d))
        fbuf = list(flat)
        embedding_q_inplace(fbuf, table, d)
        assert fbuf == per_example, "batched flat embedding walk diverged"
