"""Pinned vectors for the Qm.n scale rule (Eqs 1-4).

The same vectors are pinned in rust/src/quant tests — the contract keeping
the three layers in agreement.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.quant_math import fake_quant, frac_bits, qmn_limits, quantize_to_int


# (max_abs, width, expected_n)
PINNED_N = [
    (1.0, 8, 6),       # m = 1 -> Q1.6 (sign excluded from m per Eq 2)
    (1.98, 8, 6),
    (2.0, 8, 5),       # m = 2
    (0.49, 8, 8),      # m = -1 -> leading unused bits recovered (§4.1.4)
    (0.25, 8, 8),      # m = 1 + floor(-2) = -1
    (100.0, 8, 0),     # m = 7
    (200.0, 8, -1),    # m = 8: integer part not fully representable
    (1.0, 16, 14),
    (3.0, 16, 13),
    (0.0078125, 16, 21),  # 2^-7 -> m = -6
]


@pytest.mark.parametrize("maxabs,width,expected", PINNED_N)
def test_frac_bits_pinned(maxabs, width, expected):
    x = jnp.array([maxabs, -maxabs / 2, 0.0])
    assert int(frac_bits(x, width)) == expected


def test_frac_bits_zero_vector():
    x = jnp.zeros((4,))
    assert int(frac_bits(x, 8)) == 7


def test_quantize_saturates():
    x = jnp.array([300.0, -300.0])
    q = quantize_to_int(x, jnp.float32(0.0), 8)
    lo, hi = qmn_limits(8)
    assert q.tolist() == [float(hi), float(lo)]


def test_quantize_truncates_toward_zero():
    # Eq 3 uses trunc, not round: 1.9 -> 1, -1.9 -> -1 (at n = 0)
    q = quantize_to_int(jnp.array([1.9, -1.9]), jnp.float32(0.0), 8)
    assert q.tolist() == [1.0, -1.0]


def test_fake_quant_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=512).astype(np.float32))
    for width in (8, 9, 16):
        n = int(frac_bits(x, width))
        step = 2.0 ** (-n)
        err = np.abs(np.asarray(fake_quant(x, width)) - np.asarray(x))
        # trunc error < one step everywhere (no saturation by construction)
        assert err.max() < step + 1e-7


def test_fake_quant_idempotent():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=256).astype(np.float32))
    q1 = fake_quant(x, 8)
    q2 = fake_quant(q1, 8)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), atol=1e-7)


def test_fake_quant_gradient_is_identity():
    import jax

    g = jax.grad(lambda x: jnp.sum(fake_quant(x, 8)))(jnp.ones((4,)) * 0.3)
    np.testing.assert_allclose(np.asarray(g), np.ones(4), atol=1e-6)
