"""L2 model tests: shapes, the paper's parameter-count datapoint, training
behaviour, and QAT/Pallas-path consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def har_cfg():
    return M.make_config("har", 16)


def test_param_count_matches_paper(har_cfg):
    # §6.1.1: "an 8-bit quantization ... 3958 memory bytes to store the
    # parameters" at 16 filters -> exactly 3958 parameters.
    assert M.param_count(har_cfg) == 3958


@pytest.mark.parametrize("dataset,filters,batch", [
    ("har", 8, 3), ("smnist", 8, 3), ("gtsrb", 8, 2),
])
def test_forward_shapes(dataset, filters, batch):
    cfg = M.make_config(dataset, filters)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch,) + cfg.input_shape)
    for kwargs in ({}, {"width": 8}, {"width": 8, "use_pallas": True}):
        out = M.apply(params, x, cfg, **kwargs)
        assert out.shape == (batch, cfg.classes)
        assert bool(jnp.all(jnp.isfinite(out)))


def test_param_shapes_stable(har_cfg):
    params = M.init_params(jax.random.PRNGKey(0), har_cfg)
    assert len(params) == len(M.PARAM_NAMES) == 14
    assert params[0].shape == (3, 9, 16)
    assert params[10].shape == (1, 16, 16)  # 1x1 shortcut
    assert params[12].shape == (16, 6)


def test_train_step_decreases_loss(har_cfg):
    cfg = har_cfg
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    mom = [jnp.zeros_like(p) for p in params]
    # Learnable synthetic signal: class-dependent sinusoid.
    b = 32
    y = jnp.arange(b, dtype=jnp.int32) % cfg.classes
    t = jnp.arange(128.0)
    base = jnp.sin(t[None, :, None] * (0.05 + 0.05 * y[:, None, None]))
    x = base + 0.1 * jax.random.normal(key, (b, 128, 9))

    # The per-step training loss is mixup loss (random lam), so progress is
    # judged on the CLEAN cross-entropy before vs after, with the linear
    # warmup every coordinator LR schedule uses (lr 0.05 cold with
    # momentum 0.9 oscillates from a fresh He init, so use 0.01).
    def clean_loss(p):
        logits = M.apply(p, x, cfg)
        return float(M._cross_entropy(logits, jax.nn.one_hot(y, cfg.classes)))

    step = jax.jit(lambda p, m, kd, lr: M.train_step(
        p, m, x, y, kd, lr, cfg))
    before = clean_loss(params)
    for i in range(40):
        kd = jnp.array([0, i], dtype=jnp.uint32)
        lr = jnp.float32(0.01 * min(1.0, (i + 1) / 10.0))
        params, mom, loss = step(params, mom, kd, lr)
        assert jnp.isfinite(loss)
    after = clean_loss(params)
    assert after < before, (after, before)


def test_qat_train_step_runs(har_cfg):
    cfg = har_cfg
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    mom = [jnp.zeros_like(p) for p in params]
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 128, 9))
    y = jnp.zeros((8,), jnp.int32)
    kd = jnp.array([0, 0], dtype=jnp.uint32)
    p2, m2, loss = M.train_step(params, mom, x, y, kd, jnp.float32(0.01),
                                cfg, width=8)
    assert jnp.isfinite(loss)
    # QAT must actually update the parameters (STE gradients flow).
    moved = sum(float(jnp.max(jnp.abs(a - b))) for a, b in zip(params, p2))
    assert moved > 0


def test_weight_decay_shrinks_unused_params(har_cfg):
    # With lr > 0 and zero-ish gradients on a dead path, weight decay alone
    # must shrink the parameter norm (SGD contract of §6).
    cfg = har_cfg
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    mom = [jnp.zeros_like(p) for p in params]
    x = jnp.zeros((4, 128, 9))
    y = jnp.zeros((4,), jnp.int32)
    kd = jnp.array([0, 0], dtype=jnp.uint32)
    p2, _, _ = M.train_step(params, mom, x, y, kd, jnp.float32(0.1), cfg)
    # conv1 weight gets zero data -> only decay: ||p2|| < ||p||
    assert float(jnp.linalg.norm(p2[0])) < float(jnp.linalg.norm(params[0]))


def test_pallas_path_close_to_fake_quant_path(har_cfg):
    """The integer Pallas path and the fake-quant float path differ only in
    where truncation happens; logits must stay within a few quantization
    steps of each other."""
    cfg = har_cfg
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 128, 9))
    a = M.apply(params, x, cfg, width=8)
    b = M.apply(params, x, cfg, width=8, use_pallas=True)
    assert float(jnp.max(jnp.abs(a - b))) < 0.5


def test_accuracy_helper(har_cfg):
    cfg = har_cfg
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 128, 9))
    y = jnp.zeros((16,), jnp.int32)
    acc = M.accuracy(params, x, y, cfg)
    assert 0.0 <= float(acc) <= 1.0
